//! Distributed KV store for node features (DistDGL-style), sharded by the
//! graph partition, with RPC costs charged to the simulated [`crate::net`]
//! fabric.
//!
//! One pull entry point, [`KvStore::pull`], takes a [`PullRequest`] whose
//! [`PullKind`] mirrors the paper's two primitives:
//! - [`PullKind::Vector`] — one bulk, vectorized pull (cache builds;
//!   Algorithm 1 line 4). Fans out to owner shards in parallel.
//! - [`PullKind::Sync`] — the miss-set pull on (or near) the critical
//!   path (Algorithm 1 line 14). Same fabric, tracked separately.
//!
//! Every pull is priced through a pluggable [`Transport`] (default:
//! [`Analytic`], the closed-form fabric model); wallclock execution swaps
//! in [`crate::net::ShmRings`], which really moves the serialized shard
//! bytes between threads while charging the identical analytic price — so
//! row/byte counters stay conformant across backends. The legacy
//! `{vector,sync}_pull{,_at}` names remain as deprecated one-PR shims.
//!
//! Feature values may or may not be materialized: the trace-mode benches run
//! metadata-only (counts and charges are exact, no row copies), while full
//! runs gather real rows.

use crate::compress::BlockCodec;
use crate::graph::Dataset;
use crate::metrics::CommStats;
use crate::net::{Analytic, ChargeSpec, NetFabric, Transport};
use crate::partition::Partition;
use crate::{NodeId, WorkerId};
use std::sync::{Arc, Mutex};

/// Result of a pull operation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Pull {
    /// Simulated seconds on the requester's critical path.
    pub time: f64,
    /// Bytes moved over the fabric.
    pub bytes: u64,
    /// Remote feature rows fetched.
    pub remote_rows: u64,
    /// RPCs issued (one per touched remote shard).
    pub rpcs: u64,
}

/// Which of the paper's two pull primitives a [`PullRequest`] is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PullKind {
    /// Bulk vectorized pull (cache construction; Algorithm 1 line 4).
    /// Accounted under `CommStats::{vector_pulls, vector_rows}`.
    Vector,
    /// Miss-set pull on (or near) the critical path (Algorithm 1 line 14).
    /// Accounted under `CommStats::sync_pulls`.
    Sync,
}

/// One pull, fully described: who asks, for which nodes, in which epoch,
/// and which accounting bucket it lands in. Replaces the four-way
/// `{vector,sync}_pull{,_at}` method ladder the same way
/// [`ChargeSpec`] replaced the fabric's `charge_*` ladder.
#[derive(Debug, Clone, Copy)]
pub struct PullRequest<'a> {
    /// Worker issuing the pull (local rows cost nothing).
    pub requester: WorkerId,
    /// Node ids to fetch, gathered in this order when materializing.
    pub ids: &'a [NodeId],
    /// Training epoch, resolving transient speed phases on the charge.
    pub epoch: u32,
    /// Accounting bucket (vector vs sync).
    pub kind: PullKind,
}

impl<'a> PullRequest<'a> {
    /// Bulk vectorized pull at epoch 0 (chain [`Self::at`] for later epochs).
    pub fn vector(requester: WorkerId, ids: &'a [NodeId]) -> Self {
        PullRequest { requester, ids, epoch: 0, kind: PullKind::Vector }
    }

    /// Miss-set pull at epoch 0 (chain [`Self::at`] for later epochs).
    pub fn sync(requester: WorkerId, ids: &'a [NodeId]) -> Self {
        PullRequest { requester, ids, epoch: 0, kind: PullKind::Sync }
    }

    /// Resolve transient speed phases against `epoch`.
    pub fn at(mut self, epoch: u32) -> Self {
        self.epoch = epoch;
        self
    }
}

/// Running totals of the codec path, accumulated across every pull on the
/// store. Deliberately *not* part of [`CommStats`]: the per-epoch serialized
/// key set stays byte-stable; the coordinator snapshots this into the
/// run-level `RunReport::compression` telemetry instead.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CompressTally {
    /// Payload bytes the same pulls would have moved uncompressed
    /// (`remote_rows × 4d`, RPC envelopes excluded from both sides).
    pub raw_bytes: u64,
    /// Compressed payload bytes actually charged (rows + codec block
    /// headers; RPC envelopes excluded).
    pub wire_bytes: u64,
    /// Summed squared quantization error over round-tripped elements
    /// (only accumulates in full mode, where rows are materialized).
    pub sq_err: f64,
    /// Elements round-tripped through the codec.
    pub elems: u64,
}

/// Sharded feature store.
pub struct KvStore {
    part: Arc<Partition>,
    fabric: NetFabric,
    /// Pricing backend every pull's [`ChargeSpec`]s go through. Defaults to
    /// [`Analytic`] over `fabric`; wallclock runs install
    /// [`crate::net::ShmRings`] (which delegates pricing to the same fabric,
    /// keeping counters backend-invariant).
    transport: Arc<dyn Transport>,
    feature_dim: usize,
    /// `rank[v]` = row index of v within its owner's shard.
    rank: Vec<u32>,
    /// Per-partition feature rows (row-major); empty vecs in trace mode.
    shards: Vec<Vec<f32>>,
    /// Wire codec for remote rows; `None` = full-precision f32 (the legacy
    /// charge path, bit-exact).
    codec: Option<BlockCodec>,
    /// Codec accounting (see [`CompressTally`]); a plain mutex because pulls
    /// may run concurrently from prefetcher threads.
    tally: Mutex<CompressTally>,
}

impl KvStore {
    /// Build from a dataset + partition. Copies feature rows into per-shard
    /// storage when the dataset has materialized features.
    pub fn new(ds: &Dataset, part: Arc<Partition>, fabric: NetFabric) -> Self {
        let n = ds.graph.num_nodes() as usize;
        let d = ds.config.feature_dim as usize;
        let mut rank = vec![0u32; n];
        for locals in &part.local_nodes {
            for (i, &v) in locals.iter().enumerate() {
                rank[v as usize] = i as u32;
            }
        }
        let shards: Vec<Vec<f32>> = if ds.has_features() {
            part.local_nodes
                .iter()
                .map(|locals| {
                    let mut rows = Vec::with_capacity(locals.len() * d);
                    for &v in locals {
                        rows.extend_from_slice(ds.feature_row(v));
                    }
                    rows
                })
                .collect()
        } else {
            vec![Vec::new(); part.num_parts as usize]
        };
        KvStore {
            part,
            transport: Arc::new(Analytic::new(fabric.clone())),
            fabric,
            feature_dim: d,
            rank,
            shards,
            codec: None,
            tally: Mutex::new(CompressTally::default()),
        }
    }

    /// Install a wire codec: remote pulls charge the compressed payload and
    /// (in full mode) gather codec-round-tripped rows. `None` is the default
    /// full-precision path.
    pub fn with_codec(mut self, codec: Option<BlockCodec>) -> Self {
        self.codec = codec;
        self
    }

    /// Swap the pricing backend (see the `transport` field). The backend
    /// must price through the same fabric handle for counters to stay
    /// conformant — both shipped backends do so by construction.
    pub fn with_transport(mut self, transport: Arc<dyn Transport>) -> Self {
        self.transport = transport;
        self
    }

    /// The transport backend pulls are priced through.
    pub fn transport(&self) -> &Arc<dyn Transport> {
        &self.transport
    }

    /// The wire codec installed on this store, if any.
    pub fn codec(&self) -> Option<BlockCodec> {
        self.codec
    }

    /// Snapshot of the codec accounting accumulated since construction.
    pub fn compression_tally(&self) -> CompressTally {
        *self.tally.lock().unwrap()
    }

    /// Feature dimensionality.
    pub fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    /// Whether feature values are materialized.
    pub fn has_values(&self) -> bool {
        self.shards.iter().any(|s| !s.is_empty())
    }

    /// The fabric all pulls are charged against (topology-aware per-link
    /// stats live here — Fig-4/Fig-6 benches and failure-path tests read it).
    pub fn fabric(&self) -> &NetFabric {
        &self.fabric
    }

    /// Copy node `v`'s feature row into `out` (must be materialized).
    #[inline]
    pub fn copy_row(&self, v: NodeId, out: &mut [f32]) {
        let p = self.part.owner_of(v) as usize;
        let r = self.rank[v as usize] as usize;
        let d = self.feature_dim;
        out.copy_from_slice(&self.shards[p][r * d..(r + 1) * d]);
    }

    /// Read-only view of node `v`'s feature row.
    #[inline]
    pub fn row(&self, v: NodeId) -> &[f32] {
        let p = self.part.owner_of(v) as usize;
        let r = self.rank[v as usize] as usize;
        let d = self.feature_dim;
        &self.shards[p][r * d..(r + 1) * d]
    }

    /// Bytes held by shard `p` (Fig-7 host-memory accounting).
    pub fn shard_bytes(&self, p: WorkerId) -> u64 {
        (self.shards[p as usize].len() * 4) as u64
    }

    /// Per-shard feature blobs as little-endian `f32` bytes — the backing
    /// stores a real transport backend (e.g. [`crate::net::ShmRings`])
    /// serves payload from. Empty blobs for trace-mode (value-free) shards.
    pub fn serialized_shards(&self) -> Vec<Vec<u8>> {
        self.shards
            .iter()
            .map(|s| {
                let mut blob = Vec::with_capacity(s.len() * 4);
                for v in s {
                    blob.extend_from_slice(&v.to_le_bytes());
                }
                blob
            })
            .collect()
    }

    /// Gather rows for `ids` (in order) *without* charging the fabric or the
    /// codec tally. Checkpoint restore rebuilds caches through this so the
    /// deterministic per-link RPC counters (which drive loss-retry cadence)
    /// stay exactly where the imported checkpoint left them; the movement is
    /// priced analytically by the recovery layer instead. Remote rows still
    /// pass through the wire codec, so the restored cache holds the same
    /// dequantized bytes a charged pull would have produced. Requires
    /// materialized features ([`Self::has_values`]).
    pub fn peek_rows(&self, requester: WorkerId, ids: &[NodeId]) -> Vec<f32> {
        let d = self.feature_dim;
        let mut out = Vec::with_capacity(ids.len() * d);
        for &v in ids {
            let p = self.part.owner_of(v) as usize;
            let r = self.rank[v as usize] as usize;
            out.extend_from_slice(&self.shards[p][r * d..(r + 1) * d]);
            if let Some(codec) = self.codec {
                if p as WorkerId != requester {
                    let n = out.len();
                    codec.round_trip(&mut out[n - d..]);
                }
            }
        }
        out
    }

    /// Overwrite the codec tally (checkpoint restore; the tally is cumulative
    /// run-level state, so a resumed run imports the snapshot it saved).
    pub fn import_compression_tally(&self, t: CompressTally) {
        *self.tally.lock().unwrap() = t;
    }

    /// Internal: group `ids` by owner, charge the fabric for the remote
    /// portion, and optionally gather rows (in `ids` order) into `out`.
    /// `epoch` resolves transient speed phases on the charge.
    fn pull_impl(
        &self,
        requester: WorkerId,
        ids: &[NodeId],
        mut out: Option<&mut Vec<f32>>,
        epoch: u32,
    ) -> Pull {
        let row_bytes = (self.feature_dim * 4) as u64;
        // rows per remote owner shard
        let mut per_dst = vec![0u64; self.part.num_parts as usize];
        let mut remote_rows = 0u64;
        for &v in ids {
            let o = self.part.owner_of(v);
            if o != requester {
                per_dst[o as usize] += 1;
                remote_rows += 1;
            }
        }
        let mut sq_err = 0.0f64;
        if let Some(buf) = out.as_deref_mut() {
            buf.clear();
            buf.reserve(ids.len() * self.feature_dim);
            for &v in ids {
                let p = self.part.owner_of(v) as usize;
                let r = self.rank[v as usize] as usize;
                let d = self.feature_dim;
                buf.extend_from_slice(&self.shards[p][r * d..(r + 1) * d]);
                if let Some(codec) = self.codec {
                    // Remote rows cross the wire, so the requester only ever
                    // sees the dequantized reconstruction; local rows never
                    // leave the shard and stay exact.
                    if p as WorkerId != requester {
                        let n = buf.len();
                        sq_err += codec.round_trip(&mut buf[n - d..]);
                    }
                }
            }
        }
        let dsts: Vec<(WorkerId, u64)> = per_dst
            .iter()
            .enumerate()
            .filter(|&(_, &r)| r > 0)
            .map(|(p, &r)| (p as WorkerId, r))
            .collect();
        let specs: Vec<ChargeSpec> = match self.codec {
            None => dsts
                .iter()
                .map(|&(p, r)| ChargeSpec::rows(requester, p, r, row_bytes).at(epoch))
                .collect(),
            Some(codec) => {
                let comp_row = codec.row_payload_bytes(self.feature_dim);
                dsts.iter()
                    .map(|&(p, r)| ChargeSpec::payload(requester, p, r, r * comp_row).at(epoch))
                    .collect()
            }
        };
        let charge = self.transport.charge_many(&specs);
        if let Some(codec) = self.codec {
            if remote_rows > 0 {
                let comp_row = codec.row_payload_bytes(self.feature_dim);
                let mut t = self.tally.lock().unwrap();
                t.raw_bytes += remote_rows * row_bytes;
                t.wire_bytes += remote_rows * comp_row;
                t.sq_err += sq_err;
                t.elems += remote_rows * self.feature_dim as u64;
            }
        }
        Pull {
            time: charge.time,
            bytes: charge.bytes,
            remote_rows,
            rpcs: dsts.len() as u64,
        }
    }

    /// The single pull entry point: group `req.ids` by owner shard, charge
    /// the remote portion through the [`Transport`], account into `stats`
    /// under the request's [`PullKind`], and optionally gather rows (in
    /// `req.ids` order) into `out`. Local ids cost nothing on the fabric and
    /// are gathered free.
    pub fn pull(
        &self,
        req: PullRequest<'_>,
        out: Option<&mut Vec<f32>>,
        stats: &mut CommStats,
    ) -> Pull {
        let p = self.pull_impl(req.requester, req.ids, out, req.epoch);
        match req.kind {
            PullKind::Vector => {
                stats.vector_pulls += p.rpcs;
                stats.vector_rows += p.remote_rows;
            }
            PullKind::Sync => stats.sync_pulls += p.rpcs,
        }
        stats.remote_rows += p.remote_rows;
        stats.bytes += p.bytes;
        stats.net_time += p.time;
        p
    }

    /// Deprecated shim over [`Self::pull`] (one-PR migration window).
    #[deprecated(note = "use pull(PullRequest::vector(requester, ids), out, stats)")]
    pub fn vector_pull(
        &self,
        requester: WorkerId,
        ids: &[NodeId],
        out: Option<&mut Vec<f32>>,
        stats: &mut CommStats,
    ) -> Pull {
        self.pull(PullRequest::vector(requester, ids), out, stats)
    }

    /// Deprecated shim over [`Self::pull`] (one-PR migration window).
    #[deprecated(note = "use pull(PullRequest::vector(requester, ids).at(epoch), out, stats)")]
    pub fn vector_pull_at(
        &self,
        requester: WorkerId,
        ids: &[NodeId],
        out: Option<&mut Vec<f32>>,
        stats: &mut CommStats,
        epoch: u32,
    ) -> Pull {
        self.pull(PullRequest::vector(requester, ids).at(epoch), out, stats)
    }

    /// Deprecated shim over [`Self::pull`] (one-PR migration window).
    #[deprecated(note = "use pull(PullRequest::sync(requester, ids), out, stats)")]
    pub fn sync_pull(
        &self,
        requester: WorkerId,
        ids: &[NodeId],
        out: Option<&mut Vec<f32>>,
        stats: &mut CommStats,
    ) -> Pull {
        self.pull(PullRequest::sync(requester, ids), out, stats)
    }

    /// Deprecated shim over [`Self::pull`] (one-PR migration window).
    #[deprecated(note = "use pull(PullRequest::sync(requester, ids).at(epoch), out, stats)")]
    pub fn sync_pull_at(
        &self,
        requester: WorkerId,
        ids: &[NodeId],
        out: Option<&mut Vec<f32>>,
        stats: &mut CommStats,
        epoch: u32,
    ) -> Pull {
        self.pull(PullRequest::sync(requester, ids).at(epoch), out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DatasetConfig, DatasetPreset, FabricConfig};
    use crate::graph::build_dataset;
    use crate::partition::metis_like;

    fn setup(with_features: bool) -> (Dataset, Arc<Partition>, KvStore) {
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), with_features);
        let part = Arc::new(metis_like(&ds.graph, 2, 0));
        let kv = KvStore::new(&ds, part.clone(), NetFabric::new(FabricConfig::default()));
        (ds, part, kv)
    }

    #[test]
    fn rows_match_dataset() {
        let (ds, _, kv) = setup(true);
        for v in [0u32, 5, 100, 1999] {
            assert_eq!(kv.row(v), ds.feature_row(v));
        }
    }

    #[test]
    fn pull_gathers_in_request_order() {
        let (ds, _, kv) = setup(true);
        let ids = [9u32, 3, 500, 3];
        let mut out = Vec::new();
        let mut stats = CommStats::default();
        kv.pull(PullRequest::vector(0, &ids), Some(&mut out), &mut stats);
        let d = kv.feature_dim();
        for (i, &v) in ids.iter().enumerate() {
            assert_eq!(&out[i * d..(i + 1) * d], ds.feature_row(v));
        }
    }

    #[test]
    fn peek_rows_matches_pull_output_and_charges_nothing() {
        use crate::compress::WireCodec;
        let codec = BlockCodec::new(WireCodec::Int8, 32);
        let (_, part, kv) = setup_codec(true, Some(codec));
        let ids: Vec<u32> = part.local_nodes[1].iter().take(8).copied().collect();
        let mut pulled = Vec::new();
        let mut stats = CommStats::default();
        kv.pull(PullRequest::vector(0, &ids), Some(&mut pulled), &mut stats);
        let tally_after_pull = kv.compression_tally();
        let peeked = kv.peek_rows(0, &ids);
        assert_eq!(peeked, pulled, "peek must see the same (dequantized) bytes");
        assert_eq!(
            kv.compression_tally(),
            tally_after_pull,
            "peek must not touch the codec tally"
        );
    }

    #[test]
    fn compression_tally_import_round_trips() {
        let (_, _, kv) = setup(false);
        let t = CompressTally { raw_bytes: 10, wire_bytes: 4, sq_err: 0.5, elems: 3 };
        kv.import_compression_tally(t);
        assert_eq!(kv.compression_tally(), t);
    }

    #[test]
    fn local_ids_cost_nothing() {
        let (_, part, kv) = setup(false);
        let locals: Vec<u32> = part.local_nodes[0].iter().take(10).copied().collect();
        let mut stats = CommStats::default();
        let p = kv.pull(PullRequest::sync(0, &locals), None, &mut stats);
        assert_eq!(p.remote_rows, 0);
        assert_eq!(p.rpcs, 0);
        assert_eq!(p.time, 0.0);
        assert_eq!(stats.bytes, 0);
    }

    #[test]
    fn remote_ids_are_charged() {
        let (_, part, kv) = setup(false);
        let remotes: Vec<u32> = part.local_nodes[1].iter().take(10).copied().collect();
        let mut stats = CommStats::default();
        let p = kv.pull(PullRequest::sync(0, &remotes), None, &mut stats);
        assert_eq!(p.remote_rows, 10);
        assert_eq!(p.rpcs, 1, "all on one shard → one RPC");
        assert!(p.time > 0.0);
        assert_eq!(stats.sync_pulls, 1);
        assert_eq!(stats.remote_rows, 10);
    }

    #[test]
    fn vector_vs_sync_tracked_separately() {
        let (_, part, kv) = setup(false);
        let remotes: Vec<u32> = part.local_nodes[1].iter().take(5).copied().collect();
        let mut stats = CommStats::default();
        kv.pull(PullRequest::vector(0, &remotes), None, &mut stats);
        kv.pull(PullRequest::sync(0, &remotes), None, &mut stats);
        assert_eq!(stats.vector_pulls, 1);
        assert_eq!(stats.sync_pulls, 1);
        assert_eq!(stats.remote_rows, 10);
    }

    #[test]
    fn one_bulk_pull_beats_per_node_pulls() {
        // The VectorPull advantage the paper leans on: one vectorized RPC
        // amortizes latency over rows.
        let (_, part, kv) = setup(false);
        let remotes: Vec<u32> = part.local_nodes[1].iter().take(100).copied().collect();
        let mut s1 = CommStats::default();
        let bulk = kv.pull(PullRequest::vector(0, &remotes), None, &mut s1);
        let mut s2 = CommStats::default();
        let mut per_node_time = 0.0;
        for &v in &remotes {
            per_node_time += kv.pull(PullRequest::sync(0, &[v]), None, &mut s2).time;
        }
        assert!(per_node_time > 10.0 * bulk.time);
    }

    #[test]
    fn trace_mode_has_no_values() {
        let (_, _, kv) = setup(false);
        assert!(!kv.has_values());
    }

    fn setup_codec(
        with_features: bool,
        codec: Option<BlockCodec>,
    ) -> (Dataset, Arc<Partition>, KvStore) {
        let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), with_features);
        let part = Arc::new(metis_like(&ds.graph, 2, 0));
        let kv = KvStore::new(&ds, part.clone(), NetFabric::new(FabricConfig::default()))
            .with_codec(codec);
        (ds, part, kv)
    }

    #[test]
    fn codec_charges_compressed_payload_with_invariant_rows() {
        use crate::compress::WireCodec;
        let (_, part, plain_kv) = setup(false);
        let codec = BlockCodec::new(WireCodec::Int8, 128);
        let (_, _, quant_kv) = setup_codec(false, Some(codec));
        let remotes: Vec<u32> = part.local_nodes[1].iter().take(50).copied().collect();
        let mut s_plain = CommStats::default();
        let mut s_quant = CommStats::default();
        let plain = plain_kv.pull(PullRequest::sync(0, &remotes), None, &mut s_plain);
        let quant = quant_kv.pull(PullRequest::sync(0, &remotes), None, &mut s_quant);
        assert_eq!(quant.remote_rows, plain.remote_rows, "rows codec-invariant");
        assert_eq!(quant.rpcs, plain.rpcs);
        let d = plain_kv.feature_dim();
        assert_eq!(plain.bytes, 50 * 4 * d as u64 + 64);
        assert_eq!(quant.bytes, 50 * codec.row_payload_bytes(d) + 64);
        assert!(quant.bytes < plain.bytes);
        assert!(quant.time < plain.time, "less wire time for the same rows");
        let t = quant_kv.compression_tally();
        assert_eq!(t.raw_bytes, 50 * 4 * d as u64);
        assert_eq!(t.wire_bytes, 50 * codec.row_payload_bytes(d));
        assert_eq!(t.sq_err, 0.0, "trace mode round-trips nothing");
        assert_eq!(plain_kv.compression_tally(), CompressTally::default());
    }

    #[test]
    fn codec_round_trips_remote_rows_and_keeps_local_rows_exact() {
        use crate::compress::WireCodec;
        let codec = BlockCodec::new(WireCodec::Int8, 32);
        let (ds, part, kv) = setup_codec(true, Some(codec));
        let local = part.local_nodes[0][0];
        let remote = part.local_nodes[1][0];
        let ids = [local, remote];
        let mut out = Vec::new();
        let mut stats = CommStats::default();
        kv.pull(PullRequest::sync(0, &ids), Some(&mut out), &mut stats);
        let d = kv.feature_dim();
        assert_eq!(&out[..d], ds.feature_row(local), "local row stays exact");
        let got_remote = &out[d..2 * d];
        let mut expect = ds.feature_row(remote).to_vec();
        let se = codec.round_trip(&mut expect);
        assert_eq!(got_remote, &expect[..], "remote row is the dequantized reconstruction");
        let t = kv.compression_tally();
        assert_eq!(t.elems, d as u64);
        assert!((t.sq_err - se).abs() < 1e-12);
        // The reconstruction error is small but (generically) non-zero.
        assert!(t.sq_err >= 0.0 && t.sq_err.is_finite());
    }

    #[test]
    fn no_codec_store_reports_empty_tally() {
        let (_, part, kv) = setup(false);
        let remotes: Vec<u32> = part.local_nodes[1].iter().take(5).copied().collect();
        let mut stats = CommStats::default();
        kv.pull(PullRequest::sync(0, &remotes), None, &mut stats);
        assert_eq!(kv.codec(), None);
        assert_eq!(kv.compression_tally(), CompressTally::default());
    }

    #[test]
    fn serialized_shards_are_le_f32_rows() {
        let (ds, part, kv) = setup(true);
        let blobs = kv.serialized_shards();
        assert_eq!(blobs.len(), part.num_parts as usize);
        for (p, blob) in blobs.iter().enumerate() {
            assert_eq!(blob.len() as u64, kv.shard_bytes(p as WorkerId));
        }
        // Shard 0's first row is its first local node's feature row.
        let v0 = part.local_nodes[0][0];
        let want = ds.feature_row(v0)[0].to_le_bytes();
        assert_eq!(&blobs[0][..4], &want);
    }

    #[test]
    fn trace_mode_serializes_empty_blobs() {
        let (_, _, kv) = setup(false);
        assert!(kv.serialized_shards().iter().all(|b| b.is_empty()));
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_pull_shims_delegate_to_pull_request() {
        // One-PR migration window: the retired four-way pull ladder must be
        // pure delegation — same Pull, same CommStats accounting.
        let (_, part, old_kv) = setup(false);
        let (_, _, new_kv) = setup(false);
        let remotes: Vec<u32> = part.local_nodes[1].iter().take(5).copied().collect();
        let mut s_old = CommStats::default();
        let mut s_new = CommStats::default();
        let a = old_kv.vector_pull(0, &remotes, None, &mut s_old);
        let b = new_kv.pull(PullRequest::vector(0, &remotes), None, &mut s_new);
        assert_eq!(a, b);
        let a = old_kv.vector_pull_at(0, &remotes, None, &mut s_old, 2);
        let b = new_kv.pull(PullRequest::vector(0, &remotes).at(2), None, &mut s_new);
        assert_eq!(a, b);
        let a = old_kv.sync_pull(0, &remotes, None, &mut s_old);
        let b = new_kv.pull(PullRequest::sync(0, &remotes), None, &mut s_new);
        assert_eq!(a, b);
        let a = old_kv.sync_pull_at(0, &remotes, None, &mut s_old, 3);
        let b = new_kv.pull(PullRequest::sync(0, &remotes).at(3), None, &mut s_new);
        assert_eq!(a, b);
        assert_eq!(s_old, s_new);
    }
}
