//! Precompute throughput — serial vs parallel offline enumeration.
//!
//! The offline pass (Algorithm 1) is embarrassingly parallel by
//! construction: every batch's k-hop expansion is seeded by `H(s0, w, e, i)`
//! alone, so batches parallelize with byte-identical output (see
//! `sampler::schedule`). This bench reports batches/sec for
//! `enumerate_epoch_threads` at 1 thread vs all available threads, plus the
//! sharded frequency ranking and the partial-selection `TopHot` cut, and
//! emits `bench_results/precompute_throughput.json`.

use rapidgnn::cache::top_hot;
use rapidgnn::config::DatasetPreset;
use rapidgnn::graph::build_dataset;
use rapidgnn::sampler::{enumerate_epoch_threads, remote_frequency_threads, Fanout};
use rapidgnn::util::bench::{fmt_secs, time_until, Table};
use rapidgnn::util::bench_support::bench_dataset;
use rapidgnn::util::parallel::available_threads;
use rapidgnn::util::value::Value;

fn main() -> rapidgnn::Result<()> {
    let ds = build_dataset(&bench_dataset(DatasetPreset::ProductsSim), false);
    let part = rapidgnn::partition::metis_like(&ds.graph, 4, 0);
    let shard: Vec<u32> = ds
        .train_nodes
        .iter()
        .copied()
        .filter(|&v| part.is_local(0, v))
        .collect();
    let fanouts = [Fanout::Sample(10), Fanout::Sample(25)];
    let threads = available_threads();
    let n_batches = shard.len().div_ceil(1000);

    let mut counts = vec![1usize];
    if threads > 1 {
        counts.push(threads);
    }

    let mut t = Table::new(
        &format!(
            "Precompute throughput (products-sim, {} seeds, batch 1000, {} batches)",
            shard.len(),
            n_batches
        ),
        &["path", "per-epoch", "batches/s", "speedup"],
    );

    // --- offline enumeration: serial reference vs all cores ---
    let mut enum_secs: Vec<f64> = Vec::new();
    for &th in &counts {
        let (_, _, per) = time_until(2.0, || {
            let s =
                enumerate_epoch_threads(th, &ds.graph, &part, &shard, &fanouts, 1000, 42, 0, 0);
            std::hint::black_box(s.batches.len());
        });
        enum_secs.push(per);
        t.row(&[
            format!("enumerate_epoch ({th} threads)"),
            fmt_secs(per),
            format!("{:.1}", n_batches as f64 / per),
            format!("{:.2}x", enum_secs[0] / per),
        ]);
    }

    // --- frequency ranking: serial tally vs sharded ---
    let sched =
        enumerate_epoch_threads(threads, &ds.graph, &part, &shard, &fanouts, 1000, 42, 0, 0);
    let mut rank_secs: Vec<f64> = Vec::new();
    for &th in &counts {
        let (_, _, per) = time_until(1.0, || {
            std::hint::black_box(remote_frequency_threads(th, &sched.batches).len());
        });
        rank_secs.push(per);
        t.row(&[
            format!("remote_frequency ({th} threads)"),
            fmt_secs(per),
            "-".into(),
            format!("{:.2}x", rank_secs[0] / per),
        ]);
    }

    // --- TopHot: partial selection over the sharded tally ---
    let (_, _, top_per) = time_until(1.0, || {
        std::hint::black_box(top_hot(&sched.batches, 32_000).len());
    });
    t.row(&[
        "top_hot 32k (partial selection)".into(),
        fmt_secs(top_per),
        "-".into(),
        format!("{:.2}x", rank_secs[0] / top_per),
    ]);

    t.print();

    let serial = enum_secs[0];
    let parallel = *enum_secs.last().unwrap();
    println!(
        "enumerate speedup at {threads} threads: {:.2}x ({:.1} -> {:.1} batches/s)",
        serial / parallel,
        n_batches as f64 / serial,
        n_batches as f64 / parallel
    );

    let mut v = Value::table();
    v.set("threads", threads as u64)
        .set("n_batches", n_batches as u64)
        .set("enumerate_serial_sec", serial)
        .set("enumerate_parallel_sec", parallel)
        .set("enumerate_speedup", serial / parallel)
        .set("serial_batches_per_sec", n_batches as f64 / serial)
        .set("parallel_batches_per_sec", n_batches as f64 / parallel)
        .set("rank_serial_sec", rank_secs[0])
        .set("rank_parallel_sec", *rank_secs.last().unwrap())
        .set("top_hot_sec", top_per);
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write(
        "bench_results/precompute_throughput.json",
        v.to_json_pretty(),
    )?;
    Ok(())
}
