//! Figure 9 — convergence parity: training accuracy across batch sizes on
//! products-sim and reddit-sim, RapidGNN vs DGL-METIS.
//!
//! This is the empirical validation of Proposition 3.1: deterministic seeded
//! sampling + hot-set caching + prefetching must not bias the gradient
//! estimator — accuracy curves rise and plateau at the same level as the
//! on-demand baseline in all six configurations.
//!
//! Runs in full-exec mode with the host trainer on scaled-down datasets
//! (real forward/backward/SGD, identical model init per pair).

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, ExecMode, RunConfig};
use rapidgnn::coordinator;
use rapidgnn::util::bench::Table;
use rapidgnn::util::value::Value;

fn cfg(preset: DatasetPreset, engine: Engine, batch: u32) -> RunConfig {
    let mut ds = DatasetConfig::preset(preset, 0.12);
    ds.train_fraction = 0.5; // enough seeds for several batches per epoch
    RunConfig {
        dataset: ds,
        engine,
        exec_mode: ExecMode::Full,
        num_workers: 2,
        batch_size: batch,
        fanout: vec![5, 10],
        epochs: 6,
        n_hot: 2_000,
        learning_rate: 0.08,
        ..Default::default()
    }
}

fn main() -> rapidgnn::Result<()> {
    // batch sizes scaled to the shrunken datasets (stand-ins for the paper's
    // 1000/2000/3000 on the full graphs)
    let batches = [128u32, 256, 512];
    let mut json = Vec::new();
    for preset in [DatasetPreset::ProductsSim, DatasetPreset::RedditSim] {
        for batch in batches {
            let rapid = coordinator::run(&cfg(preset, Engine::Rapid, batch))?;
            // The baseline's sampler draws from a DIFFERENT seed stream —
            // simulating DGL's online RNG — so overlap demonstrates the
            // distributional equivalence of Proposition 3.1, not bit-equality.
            let mut mcfg = cfg(preset, Engine::DglMetis, batch);
            mcfg.base_seed = mcfg.base_seed.wrapping_add(0xD61);
            let metis = coordinator::run(&mcfg)?;
            let ra = rapid.accuracy_curve();
            let ma = metis.accuracy_curve();
            let mut t = Table::new(
                &format!("Fig 9 — {} batch {}", preset.name(), batch),
                &["epoch", "RapidGNN acc", "DGL-METIS acc", "gap"],
            );
            for ((e, a), (_, b)) in ra.iter().zip(&ma) {
                t.row(&[
                    e.to_string(),
                    format!("{:.1}%", a * 100.0),
                    format!("{:.1}%", b * 100.0),
                    format!("{:+.1}pp", (a - b) * 100.0),
                ]);
            }
            t.print();
            let final_gap = ra.last().unwrap().1 - ma.last().unwrap().1;
            println!(
                "final-accuracy gap: {:+.1}pp (paper: curves overlap; both rise and plateau)",
                final_gap * 100.0
            );
            let mut cell = Value::table();
            cell.set("dataset", preset.name())
                .set("batch", batch)
                .set("rapid_final_acc", ra.last().unwrap().1)
                .set("metis_final_acc", ma.last().unwrap().1)
                .set(
                    "rapid_curve",
                    Value::Arr(ra.iter().map(|&(_, a)| Value::Float(a)).collect()),
                )
                .set(
                    "metis_curve",
                    Value::Arr(ma.iter().map(|&(_, a)| Value::Float(a)).collect()),
                );
            json.push(cell);
        }
    }
    // Compression convergence cells: error-fed top-k gradient sparsification
    // at k = 10% against the dense update, same seed stream (identical
    // sampling — only the optimizer step differs, so the gap isolates the
    // compression effect). Gate: final loss within 2% relative of dense.
    for preset in [DatasetPreset::ProductsSim, DatasetPreset::RedditSim] {
        let batch = 256u32;
        let dense = coordinator::run(&cfg(preset, Engine::Rapid, batch))?;
        let sparse = coordinator::run(&cfg(preset, Engine::GradTopk, batch))?;
        let dl = dense.loss_curve();
        let sl = sparse.loss_curve();
        let mut t = Table::new(
            &format!("Fig 9b — {} batch {}: dense vs grad-topk k=10%", preset.name(), batch),
            &["epoch", "dense loss", "top-k loss", "gap"],
        );
        for ((e, a), (_, b)) in dl.iter().zip(&sl) {
            t.row(&[
                e.to_string(),
                format!("{a:.4}"),
                format!("{b:.4}"),
                format!("{:+.2}%", (b - a) / a * 100.0),
            ]);
        }
        t.print();
        let (fd, fs) = (dl.last().unwrap().1, sl.last().unwrap().1);
        let rel = (fs - fd).abs() / fd;
        println!(
            "grad-topk final-loss gap: {:.2}% relative (gate: < 2%)",
            rel * 100.0
        );
        assert!(
            rel < 0.02,
            "{}: grad-topk final loss {fs:.4} strays {:.2}% from dense {fd:.4}",
            preset.name(),
            rel * 100.0
        );
        let comp = sparse
            .compression
            .as_ref()
            .expect("grad-topk must report gradient telemetry");
        let mut cell = Value::table();
        cell.set("dataset", preset.name())
            .set("batch", batch)
            .set("dense_final_loss", fd)
            .set("grad_topk_final_loss", fs)
            .set("grad_elems_sent", comp.grad_elems_sent)
            .set("grad_elems_total", comp.grad_elems_total)
            .set(
                "dense_loss_curve",
                Value::Arr(dl.iter().map(|&(_, l)| Value::Float(l)).collect()),
            )
            .set(
                "grad_topk_loss_curve",
                Value::Arr(sl.iter().map(|&(_, l)| Value::Float(l)).collect()),
            );
        json.push(cell);
    }
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig9.json", Value::Arr(json).to_json_pretty())?;
    Ok(())
}
