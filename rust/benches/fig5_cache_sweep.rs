//! Figure 5 — average remote feature fetches per epoch vs cache size.
//!
//! Paper: products, 2 machines, batch {1000,2000,3000}; fetches fall sharply
//! through the low-to-moderate cache range (the long-tail hot set) and then
//! flatten — diminishing returns guide practical cache sizing. We count the
//! critical-path fetches (SyncPull misses; cache-build VectorPulls excluded,
//! matching the paper's "remote feature fetches" on the training path).
//!
//! Extended with adaptive-vs-static cells: the `adaptive-cache` controller,
//! started well below the knee, must climb to within 5 percentage points of
//! the best static hit rate anywhere in the sweep — without ever exceeding
//! its `max_hot` memory envelope (the gate asserted below). A second cell
//! starts oversized and shows the shrink side: capacity monotonically
//! released while the clamps hold.

use rapidgnn::config::{DatasetPreset, Engine};
use rapidgnn::coordinator;
use rapidgnn::metrics::RunReport;
use rapidgnn::util::bench::Table;
use rapidgnn::util::bench_support::{paper_run, FIG5_CACHE_SIZES, PAPER_BATCHES};
use rapidgnn::util::value::Value;

fn main() -> rapidgnn::Result<()> {
    let mut t = Table::new(
        "Fig 5 — remote fetches/epoch vs cache size (products-sim, P=2)",
        &["n_hot", "batch 1000", "batch 2000", "batch 3000"],
    );
    let mut json = Vec::new();
    let mut per_batch: Vec<Vec<f64>> = vec![Vec::new(); PAPER_BATCHES.len()];
    let mut hit_by_batch: Vec<Vec<f64>> = vec![Vec::new(); PAPER_BATCHES.len()];
    for &n_hot in &FIG5_CACHE_SIZES {
        let mut row = vec![n_hot.to_string()];
        for (bi, &batch) in PAPER_BATCHES.iter().enumerate() {
            let mut cfg = paper_run(DatasetPreset::ProductsSim, Engine::Rapid, batch);
            cfg.num_workers = 2; // paper's Fig-5 setup
            cfg.n_hot = n_hot;
            cfg.epochs = 6;
            let report = coordinator::run(&cfg)?;
            let fetches = report.sync_remote_rows() as f64
                / (cfg.epochs * cfg.num_workers) as f64;
            row.push(format!("{fetches:.0}"));
            per_batch[bi].push(fetches);
            hit_by_batch[bi].push(report.cache_hit_rate());
            let mut cell = Value::table();
            cell.set("n_hot", n_hot)
                .set("batch", batch)
                .set("fetches_per_epoch", fetches)
                .set("hit_rate", report.cache_hit_rate());
            json.push(cell);
        }
        t.row(&row);
    }
    t.print();
    // shape check: marginal fetches saved per added cache entry declines
    // sharply — the paper's diminishing-returns knee.
    for (bi, series) in per_batch.iter().enumerate() {
        let early = (series[0] - series[1]) / (FIG5_CACHE_SIZES[1] - FIG5_CACHE_SIZES[0]) as f64;
        let n = series.len();
        let late = (series[n - 2] - series[n - 1])
            / (FIG5_CACHE_SIZES[n - 1] - FIG5_CACHE_SIZES[n - 2]) as f64;
        println!(
            "batch {}: {:.1} fetches saved per cache entry early vs {:.2} late ({:.0}x marginal decay)",
            PAPER_BATCHES[bi],
            early,
            late,
            early / late.max(1e-9)
        );
    }

    // --- adaptive vs static: the controller sweeps itself. Gate: starting
    // at the sweep's second-smallest size, the grown cache's steady-state
    // (final-epoch) hit rate lands within 5 points of the best static cell,
    // and n_hot never exceeds max_hot.
    let max_hot = *FIG5_CACHE_SIZES.last().unwrap();
    let mut at = Table::new(
        "Fig 5b — adaptive controller vs best static cell (products-sim, P=2)",
        &["batch", "cell", "start", "final n_hot", "resizes", "final hit", "best static"],
    );
    for (bi, &batch) in PAPER_BATCHES.iter().enumerate() {
        let best_static = hit_by_batch[bi].iter().cloned().fold(0.0, f64::max);
        let adaptive = |start: u32, target: f64, tail: f64| -> rapidgnn::Result<RunReport> {
            let mut cfg = paper_run(DatasetPreset::ProductsSim, Engine::AdaptiveCache, batch);
            cfg.num_workers = 2;
            cfg.epochs = 8; // headroom for the size trajectory to settle
            cfg.n_hot = start;
            cfg.engine_params.resize_period = 1;
            cfg.engine_params.min_hot = 64;
            cfg.engine_params.max_hot = max_hot;
            cfg.engine_params.target_hit_rate = target;
            cfg.engine_params.tail_utility = tail;
            cfg.engine_params.hot_growth = 2.0;
            coordinator::run(&cfg)
        };
        let emit = |at: &mut Table, json: &mut Vec<Value>, cell: &str, start: u32, r: &RunReport| {
            let last = r.epochs.iter().map(|e| e.epoch).max();
            let final_n = r
                .cache_timeline()
                .filter(|(e, _)| Some(e.epoch) == last)
                .map(|(_, cp)| cp.n_hot)
                .max()
                .unwrap_or(0);
            let resizes = r.cache_timeline().map(|(_, cp)| cp.resize_events).max().unwrap_or(0);
            at.row(&[
                batch.to_string(),
                cell.into(),
                start.to_string(),
                final_n.to_string(),
                resizes.to_string(),
                format!("{:.1}%", 100.0 * r.final_epoch_hit_rate()),
                format!("{:.1}%", 100.0 * best_static),
            ]);
            let mut v = Value::table();
            v.set("batch", batch)
                .set("cell", cell)
                .set("start_n_hot", start)
                .set("final_n_hot", final_n)
                .set("resize_events", resizes)
                .set("final_epoch_hit_rate", r.final_epoch_hit_rate())
                .set("best_static_hit_rate", best_static)
                .set("peak_n_hot", r.peak_n_hot());
            json.push(v);
        };

        // Grow cell: undersized start, growth-only controller.
        let grow = adaptive(FIG5_CACHE_SIZES[1], 1.0, 0.0)?;
        emit(&mut at, &mut json, "grow", FIG5_CACHE_SIZES[1], &grow);
        assert!(
            grow.peak_n_hot() <= max_hot,
            "batch {batch}: adaptive exceeded max_hot ({} > {max_hot})",
            grow.peak_n_hot()
        );
        assert!(
            grow.final_epoch_hit_rate() >= best_static - 0.05,
            "batch {batch}: adaptive steady-state hit {:.3} below best static {:.3} - 5%",
            grow.final_epoch_hit_rate(),
            best_static
        );

        // Shrink cell: oversized start, shrink-only controller — shows the
        // memory released once the marginal tail stops earning its keep.
        let shrink = adaptive(max_hot, 0.0, 0.02)?;
        emit(&mut at, &mut json, "shrink", max_hot, &shrink);
        let mut prev = u32::MAX;
        for (e, cp) in shrink.cache_timeline().filter(|(e, _)| e.worker == 0) {
            assert!(cp.n_hot <= prev, "epoch {}: shrink-only run grew", e.epoch);
            assert!(cp.n_hot >= 64 && cp.n_hot <= max_hot, "clamps violated");
            prev = cp.n_hot;
        }
    }
    at.print();
    println!("(gate: grow-cell final-epoch hit rate within 5 points of best static cell)");

    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig5.json", Value::Arr(json).to_json_pretty())?;
    Ok(())
}
