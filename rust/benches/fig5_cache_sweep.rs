//! Figure 5 — average remote feature fetches per epoch vs cache size.
//!
//! Paper: products, 2 machines, batch {1000,2000,3000}; fetches fall sharply
//! through the low-to-moderate cache range (the long-tail hot set) and then
//! flatten — diminishing returns guide practical cache sizing. We count the
//! critical-path fetches (SyncPull misses; cache-build VectorPulls excluded,
//! matching the paper's "remote feature fetches" on the training path).

use rapidgnn::config::{DatasetPreset, Engine};
use rapidgnn::coordinator;
use rapidgnn::util::bench::Table;
use rapidgnn::util::bench_support::{paper_run, FIG5_CACHE_SIZES, PAPER_BATCHES};
use rapidgnn::util::value::Value;

fn main() -> rapidgnn::Result<()> {
    let mut t = Table::new(
        "Fig 5 — remote fetches/epoch vs cache size (products-sim, P=2)",
        &["n_hot", "batch 1000", "batch 2000", "batch 3000"],
    );
    let mut json = Vec::new();
    let mut per_batch: Vec<Vec<f64>> = vec![Vec::new(); PAPER_BATCHES.len()];
    for &n_hot in &FIG5_CACHE_SIZES {
        let mut row = vec![n_hot.to_string()];
        for (bi, &batch) in PAPER_BATCHES.iter().enumerate() {
            let mut cfg = paper_run(DatasetPreset::ProductsSim, Engine::Rapid, batch);
            cfg.num_workers = 2; // paper's Fig-5 setup
            cfg.n_hot = n_hot;
            cfg.epochs = 6;
            let report = coordinator::run(&cfg)?;
            let fetches = report.sync_remote_rows() as f64
                / (cfg.epochs * cfg.num_workers) as f64;
            row.push(format!("{fetches:.0}"));
            per_batch[bi].push(fetches);
            let mut cell = Value::table();
            cell.set("n_hot", n_hot)
                .set("batch", batch)
                .set("fetches_per_epoch", fetches)
                .set("hit_rate", report.cache_hit_rate());
            json.push(cell);
        }
        t.row(&row);
    }
    t.print();
    // shape check: marginal fetches saved per added cache entry declines
    // sharply — the paper's diminishing-returns knee.
    for (bi, series) in per_batch.iter().enumerate() {
        let early = (series[0] - series[1]) / (FIG5_CACHE_SIZES[1] - FIG5_CACHE_SIZES[0]) as f64;
        let n = series.len();
        let late = (series[n - 2] - series[n - 1])
            / (FIG5_CACHE_SIZES[n - 1] - FIG5_CACHE_SIZES[n - 2]) as f64;
        println!(
            "batch {}: {:.1} fetches saved per cache entry early vs {:.2} late ({:.0}x marginal decay)",
            PAPER_BATCHES[bi],
            early,
            late,
            early / late.max(1e-9)
        );
    }
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig5.json", Value::Arr(json).to_json_pretty())?;
    Ok(())
}
