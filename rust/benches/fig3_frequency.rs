//! Figure 3 — frequency distribution of remote feature accesses per node.
//!
//! Paper (OGBN-Products, one epoch): a power-law distribution where 45.3% of
//! remote nodes are accessed exactly once, with a long tail to a maximum
//! frequency of 66 — the property that makes a small hot-set cache so
//! effective. We regenerate the histogram from one precomputed epoch.

use rapidgnn::config::{DatasetPreset, Engine};
use rapidgnn::coordinator::{precompute, epoch_remote_frequency, RunContext};
use rapidgnn::util::bench::Table;
use rapidgnn::util::bench_support::paper_run;
use rapidgnn::util::value::Value;

fn main() -> rapidgnn::Result<()> {
    let cfg = paper_run(DatasetPreset::ProductsSim, Engine::Rapid, 1000);
    let ctx = RunContext::build(&cfg)?;
    // run the offline enumeration so the epoch schedule is on disk
    let _ = precompute(&ctx, 0)?;
    let freq = epoch_remote_frequency(&ctx, 0, 0)?;

    let total_nodes = freq.len() as f64;
    let total_accesses: u64 = freq.iter().map(|&(_, c)| c as u64).sum();
    let max_freq = freq.first().map(|&(_, c)| c).unwrap_or(0);

    // histogram over power-of-two buckets
    let mut buckets: Vec<(String, u64)> = Vec::new();
    let mut lo = 1u32;
    while lo <= max_freq {
        let hi = lo * 2 - 1;
        let count = freq.iter().filter(|&&(_, c)| c >= lo && c <= hi).count() as u64;
        buckets.push((
            if lo == hi {
                format!("{lo}")
            } else {
                format!("{lo}-{hi}")
            },
            count,
        ));
        lo *= 2;
    }

    let mut t = Table::new(
        "Fig 3 — remote feature access frequency (products-sim, 1 epoch, worker 0)",
        &["freq", "nodes", "% of nodes", "bar"],
    );
    for (label, count) in &buckets {
        let pct = 100.0 * *count as f64 / total_nodes;
        t.row(&[
            label.clone(),
            count.to_string(),
            format!("{pct:.1}%"),
            "#".repeat((pct / 2.0).ceil() as usize),
        ]);
    }
    t.print();

    let once = freq.iter().filter(|&&(_, c)| c == 1).count() as f64 / total_nodes;
    let top10 = (total_nodes * 0.1).ceil() as usize;
    let top10_mass: u64 = freq.iter().take(top10).map(|&(_, c)| c as u64).sum();
    println!(
        "accessed exactly once: {:.1}% (paper: 45.3%) | max frequency: {} (paper: 66) | top-10% nodes hold {:.1}% of accesses",
        once * 100.0,
        max_freq,
        100.0 * top10_mass as f64 / total_accesses as f64
    );

    let mut v = Value::table();
    v.set("once_fraction", once)
        .set("max_freq", max_freq)
        .set("total_remote_nodes", total_nodes as u64)
        .set("top10_mass", top10_mass as f64 / total_accesses as f64);
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig3.json", v.to_json_pretty())?;
    Ok(())
}
