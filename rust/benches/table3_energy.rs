//! Table 3 + Figure 8 — energy and power, RapidGNN vs DGL-METIS.
//!
//! Paper setup: OGBN-Products, batch 3000, 10 epochs, 3 machines. Results:
//! CPU 1376 J vs 2465 J (−44%), GPU 2310 J vs 3401 J (−32%); RapidGNN's mean
//! CPU power is *lower* (36.7 vs 42.7 W — no busy-wait RPC polling) while its
//! mean GPU power is slightly *higher* (+4.7%, device-resident cache); the
//! dominant savings channel is the 35% shorter run (37.5 s vs 57.7 s).

use rapidgnn::config::{DatasetPreset, Engine};
use rapidgnn::coordinator;
use rapidgnn::energy::epoch_energy;
use rapidgnn::metrics::RunReport;
use rapidgnn::util::bench::Table;
use rapidgnn::util::bench_support::paper_run;
use rapidgnn::util::value::Value;

fn run(engine: Engine) -> rapidgnn::Result<RunReport> {
    let mut cfg = paper_run(DatasetPreset::ProductsSim, engine, 3000);
    cfg.num_workers = 3; // paper's Table-3 setup
    cfg.epochs = 10;
    // Mid-knee cache: the paper's Table-3 run predates its Fig-5 sweep and
    // its power deltas imply a moderate cache operating point.
    cfg.n_hot = 12_000;
    coordinator::run(&cfg)
}

struct EnergyRows {
    total: f64,
    mean: f64,
    min: f64,
    max: f64,
    power: f64,
    duration: f64,
}

fn per_device(report: &RunReport, gpu: bool) -> EnergyRows {
    let power_cfg = rapidgnn::config::PowerConfig::default();
    // per-epoch energies (averaged across workers within an epoch)
    let mut by_epoch: std::collections::BTreeMap<u32, (f64, f64)> = Default::default();
    for e in &report.epochs {
        let er = epoch_energy(&e.phases, &power_cfg, e.device_bytes);
        let (j, t) = if gpu {
            (er.gpu.total_j, er.gpu.duration_s)
        } else {
            (er.cpu.total_j, er.cpu.duration_s)
        };
        let slot = by_epoch.entry(e.epoch).or_insert((0.0, 0.0));
        slot.0 += j;
        slot.1 += t;
    }
    let energies: Vec<f64> = by_epoch.values().map(|&(j, _)| j).collect();
    let durations: Vec<f64> = by_epoch.values().map(|&(_, t)| t).collect();
    let total: f64 = energies.iter().sum();
    let dur: f64 = durations.iter().sum::<f64>() / report.num_workers as f64;
    EnergyRows {
        total,
        mean: total / energies.len() as f64,
        min: energies.iter().cloned().fold(f64::INFINITY, f64::min),
        max: energies.iter().cloned().fold(0.0, f64::max),
        power: total / (dur * report.num_workers as f64),
        duration: dur,
    }
}

fn main() -> rapidgnn::Result<()> {
    let rapid = run(Engine::Rapid)?;
    let metis = run(Engine::DglMetis)?;

    let mut t = Table::new(
        "Table 3 — energy & performance (products-sim, batch 3000, 10 epochs, P=3)",
        &["metric", "CPU Rapid", "CPU DGLM", "GPU Rapid", "GPU DGLM"],
    );
    let rc = per_device(&rapid, false);
    let mc = per_device(&metis, false);
    let rg = per_device(&rapid, true);
    let mg = per_device(&metis, true);
    let fmt = |x: f64| format!("{x:.2}");
    t.row(&["Total Energy (J)".into(), fmt(rc.total), fmt(mc.total), fmt(rg.total), fmt(mg.total)]);
    t.row(&[
        "Mean Energy/Epoch (J)".into(),
        fmt(rc.mean),
        fmt(mc.mean),
        fmt(rg.mean),
        fmt(mg.mean),
    ]);
    t.row(&["Min Energy/Epoch (J)".into(), fmt(rc.min), fmt(mc.min), fmt(rg.min), fmt(mg.min)]);
    t.row(&["Max Energy/Epoch (J)".into(), fmt(rc.max), fmt(mc.max), fmt(rg.max), fmt(mg.max)]);
    t.row(&["Mean Power (W)".into(), fmt(rc.power), fmt(mc.power), fmt(rg.power), fmt(mg.power)]);
    t.row(&[
        "Total Duration (s)".into(),
        fmt(rc.duration),
        fmt(mc.duration),
        fmt(rg.duration),
        fmt(mg.duration),
    ]);
    t.print();

    println!(
        "\nFig 8 — savings: CPU {:.0}% (paper 44%), GPU {:.0}% (paper 32%)",
        100.0 * (1.0 - rc.total / mc.total),
        100.0 * (1.0 - rg.total / mg.total)
    );
    println!(
        "CPU power delta: {:.1}% (paper -14%) | GPU power delta: {:+.1}% (paper +4.7%) | duration -{:.0}% (paper -35%)",
        100.0 * (rc.power / mc.power - 1.0),
        100.0 * (rg.power / mg.power - 1.0),
        100.0 * (1.0 - rc.duration / mc.duration),
    );

    let mut v = Value::table();
    v.set("cpu_rapid_j", rc.total)
        .set("cpu_metis_j", mc.total)
        .set("gpu_rapid_j", rg.total)
        .set("gpu_metis_j", mg.total)
        .set("cpu_rapid_w", rc.power)
        .set("cpu_metis_w", mc.power)
        .set("gpu_rapid_w", rg.power)
        .set("gpu_metis_w", mg.power)
        .set("rapid_duration_s", rc.duration)
        .set("metis_duration_s", mc.duration);
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/table3.json", v.to_json_pretty())?;
    Ok(())
}
