//! Ablations over RapidGNN's design choices (DESIGN.md §7 extensions).
//!
//! The paper motivates three decisions without ablating them; we do:
//! 1. **Cache policy** — frequency-ranked `TopHot` (paper) vs degree-ranked
//!    (the obvious structural proxy) vs random contents. Frequency ranking
//!    should win because access frequency ≠ degree under per-epoch sampled
//!    schedules.
//! 2. **Prefetch window** — Q=0 (no overlap) … Q=16: communication hiding.
//! 3. **Double-buffer swap** — per-epoch refreshed cache (paper) vs a
//!    static epoch-0 cache: quantifies what the C_sec rebuild buys.
//! 4. **Coverage-driven n_hot** — `recommend_n_hot` (our autotuner) vs the
//!    manual sweep: the recommendation should land at the knee.

use rapidgnn::cache::{recommend_n_hot, top_hot, CacheBuffer, DoubleBufferCache};
use rapidgnn::config::{DatasetPreset, Engine};
use rapidgnn::coordinator::{self, RunContext};
use rapidgnn::metrics::CommStats;
use rapidgnn::prefetch::stage_batch;
use rapidgnn::sampler::seed::{mix64, Rng};
use rapidgnn::sampler::enumerate_epoch;
use rapidgnn::util::bench::Table;
use rapidgnn::util::bench_support::paper_run;
use rapidgnn::NodeId;
use std::sync::Mutex;

fn main() -> rapidgnn::Result<()> {
    let cfg = paper_run(DatasetPreset::ProductsSim, Engine::Rapid, 1000);
    let ctx = RunContext::build(&cfg)?;
    let fanouts = ctx.fanouts();
    let sched = enumerate_epoch(
        &ctx.ds.graph,
        &ctx.part,
        &ctx.shards[0],
        &fanouts,
        cfg.batch_size,
        cfg.base_seed,
        0,
        0,
    );

    // ---------- 1. cache policy ----------
    let n_hot = cfg.n_hot as usize;
    let freq_nodes = top_hot(&sched.batches, cfg.n_hot);
    // degree-ranked remote nodes
    let mut remote: Vec<NodeId> = {
        let mut seen = std::collections::BTreeSet::new();
        sched
            .batches
            .iter()
            .flat_map(|b| b.remote_nodes())
            .filter(|v| seen.insert(*v))
            .collect()
    };
    remote.sort_unstable_by_key(|&v| std::cmp::Reverse(ctx.ds.graph.degree(v)));
    let degree_nodes: Vec<NodeId> = remote.iter().take(n_hot).copied().collect();
    // random contents (deterministic shuffle)
    let mut rng = Rng::new(mix64(7));
    let mut shuffled = remote.clone();
    for i in (1..shuffled.len()).rev() {
        let j = rng.below(i as u32 + 1) as usize;
        shuffled.swap(i, j);
    }
    let random_nodes: Vec<NodeId> = shuffled.iter().take(n_hot).copied().collect();

    let mut t = Table::new(
        "Ablation 1 — cache contents policy (products-sim, 1 epoch, n_hot=10k)",
        &["policy", "hit rate", "misses/epoch"],
    );
    for (name, nodes) in [
        ("frequency (paper)", &freq_nodes),
        ("degree-ranked", &degree_nodes),
        ("random", &random_nodes),
    ] {
        let cache = Mutex::new({
            let mut c = DoubleBufferCache::default();
            c.install_steady(CacheBuffer::new(nodes, Vec::new(), ctx.kv.feature_dim()));
            c
        });
        let mut stats = CommStats::default();
        let mut misses = 0u64;
        for meta in sched.batches.iter().cloned() {
            misses += stage_batch(&ctx.kv, &cache, meta, 0, false, &mut stats).misses as u64;
        }
        let s = cache.lock().unwrap().stats();
        t.row(&[
            name.into(),
            format!("{:.1}%", s.hit_rate() * 100.0),
            misses.to_string(),
        ]);
    }
    t.print();

    // ---------- 2. prefetch window ----------
    let mut t = Table::new(
        "Ablation 2 — prefetch window Q (products-sim)",
        &["Q", "mean step time", "trainer stall/step"],
    );
    for q in [1u32, 2, 4, 8, 16] {
        let mut c = cfg.clone();
        c.prefetch_q = q;
        let r = coordinator::run(&c)?;
        t.row(&[
            q.to_string(),
            rapidgnn::util::bench::fmt_secs(r.mean_step_time()),
            rapidgnn::util::bench::fmt_secs(r.mean_net_time_per_step()),
        ]);
    }
    // Q=0 equivalent: the on-demand baseline with METIS partitions
    let base = coordinator::run(&paper_run(DatasetPreset::ProductsSim, Engine::DglMetis, 1000))?;
    t.row(&[
        "0 (= on-demand)".into(),
        rapidgnn::util::bench::fmt_secs(base.mean_step_time()),
        rapidgnn::util::bench::fmt_secs(base.mean_net_time_per_step()),
    ]);
    t.print();

    // ---------- 3. per-epoch swap vs static cache ----------
    // Static: stage every epoch against epoch-0's hot set.
    let mut t = Table::new(
        "Ablation 3 — double-buffer refresh vs static epoch-0 cache",
        &["cache", "hit rate (epochs 1..3)"],
    );
    for (name, refresh) in [("refreshed (paper)", true), ("static", false)] {
        let mut total = rapidgnn::metrics::CacheStats::default();
        let cache = Mutex::new({
            let mut c = DoubleBufferCache::default();
            c.install_steady(CacheBuffer::new(&freq_nodes, Vec::new(), ctx.kv.feature_dim()));
            c
        });
        for epoch in 1..4u32 {
            let s = enumerate_epoch(
                &ctx.ds.graph,
                &ctx.part,
                &ctx.shards[0],
                &fanouts,
                cfg.batch_size,
                cfg.base_seed,
                0,
                epoch,
            );
            if refresh {
                let hot = top_hot(&s.batches, cfg.n_hot);
                cache
                    .lock()
                    .unwrap()
                    .install_steady(CacheBuffer::new(&hot, Vec::new(), ctx.kv.feature_dim()));
            }
            let mut stats = CommStats::default();
            for meta in s.batches.iter().cloned() {
                stage_batch(&ctx.kv, &cache, meta, 0, false, &mut stats);
            }
            total.merge(&cache.lock().unwrap().stats());
            cache.lock().unwrap().reset_stats();
        }
        t.row(&[name.into(), format!("{:.1}%", total.hit_rate() * 100.0)]);
    }
    t.print();

    // ---------- 4. coverage-driven n_hot ----------
    let mut t = Table::new(
        "Ablation 4 — recommend_n_hot coverage targets",
        &["coverage", "recommended n_hot", "achieved hit rate"],
    );
    for coverage in [0.5f64, 0.7, 0.8, 0.9] {
        let k = recommend_n_hot(&sched.batches, coverage);
        let nodes = top_hot(&sched.batches, k);
        let cache = Mutex::new({
            let mut c = DoubleBufferCache::default();
            c.install_steady(CacheBuffer::new(&nodes, Vec::new(), ctx.kv.feature_dim()));
            c
        });
        let mut stats = CommStats::default();
        for meta in sched.batches.iter().cloned() {
            stage_batch(&ctx.kv, &cache, meta, 0, false, &mut stats);
        }
        let hit = cache.lock().unwrap().stats().hit_rate();
        t.row(&[
            format!("{:.0}%", coverage * 100.0),
            k.to_string(),
            format!("{:.1}%", hit * 100.0),
        ]);
    }
    t.print();
    println!("(achieved hit rate ≈ coverage target — the autotuner lands on the Fig-5 knee)");
    Ok(())
}
