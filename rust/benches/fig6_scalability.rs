//! Figure 6 — throughput scaling with worker count, across interconnect
//! topologies.
//!
//! Paper: RapidGNN scales near-linearly; at P=3 speedup 1.5× (products) to
//! 1.6× (reddit) over P=2; at P=4, 1.7–2.1×. We sweep P ∈ {2,4,8,16}
//! (extending past the paper's 4-machine testbed) on all three datasets and
//! six fabric topologies (flat switch, 2-rack spine oversubscribed 8×,
//! ring, star/parameter-server, 4-pod fat tree, 2×2 dragonfly — see
//! `rust/src/sim/README.md` for how a bench selects a topology: set
//! `cfg.fabric.topology`). A final sweep turns on shared-link queueing
//! (`fabric.contention`) over the two-tier oversubscription axis and dumps
//! per-link utilization telemetry to `bench_results/fig6_links.json`.
//!
//! Conformance gate (per ISSUE 2): for every (topology × P) cell the
//! event-driven full mode must report *identical* `total_remote_rows()` to
//! trace mode, and on the homogeneous flat topology the event makespan must
//! match the closed-form `pipeline_schedule` within 1e-9 (the cluster
//! runtime's per-worker timelines equal the recurrence, so trace epoch time
//! doubles as the closed-form reference). The identity cells run on a
//! 0.1×-scaled reddit-sim so real full-mode SGD stays tractable at P=16.

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, ExecMode, RunConfig, Topology};
use rapidgnn::coordinator;
use rapidgnn::util::bench::{fmt_secs, Table};
use rapidgnn::util::bench_support::paper_run;
use rapidgnn::util::value::Value;

const WORKERS: [u32; 4] = [2, 4, 8, 16];

fn topologies() -> Vec<(&'static str, Topology)> {
    vec![
        ("flat", Topology::Flat),
        ("2tier-8x", Topology::TwoTier { racks: 2, oversubscription: 8.0 }),
        ("ring", Topology::Ring),
        ("star", Topology::Star { hub: 0 }),
        ("fat-tree-4", Topology::FatTree { k: 4 }),
        ("dragonfly-2x2", Topology::Dragonfly { groups: 2, routers: 2 }),
    ]
}

/// Small full-mode-capable config for the per-cell trace/full identity gate.
fn identity_cfg(topo: Topology, workers: u32, mode: ExecMode) -> RunConfig {
    let mut cfg = RunConfig {
        dataset: DatasetConfig::preset(DatasetPreset::RedditSim, 0.1),
        engine: Engine::Rapid,
        num_workers: workers,
        batch_size: 64,
        epochs: 2,
        n_hot: 2_000,
        exec_mode: mode,
        ..Default::default()
    };
    cfg.dataset.train_fraction = 0.66;
    cfg.fabric.topology = topo;
    cfg
}

fn main() -> rapidgnn::Result<()> {
    let mut json = Vec::new();

    // --- scaling sweep: topology × P, trace mode, paper-scale datasets
    for preset in DatasetPreset::PAPER {
        for (tname, topo) in topologies() {
            let mut t = Table::new(
                &format!("Fig 6 — RapidGNN scaling on {} over {tname}", preset.name()),
                &["P", "epoch time", "speedup vs P=2", "DGL-METIS epoch", "Rapid vs METIS"],
            );
            let mut p2 = 0.0;
            for &p in &WORKERS {
                let mut cfg = paper_run(preset, Engine::Rapid, 1000);
                cfg.num_workers = p;
                cfg.fabric.topology = topo;
                let rapid = coordinator::run(&cfg)?;
                let mut bcfg = paper_run(preset, Engine::DglMetis, 1000);
                bcfg.num_workers = p;
                bcfg.fabric.topology = topo;
                let metis = coordinator::run(&bcfg)?;
                let epoch = rapid.total_time / cfg.epochs as f64;
                let metis_epoch = metis.total_time / bcfg.epochs as f64;
                if p == 2 {
                    p2 = epoch;
                }
                t.row(&[
                    p.to_string(),
                    fmt_secs(epoch),
                    format!("{:.2}x", p2 / epoch),
                    fmt_secs(metis_epoch),
                    format!("{:.2}x", metis_epoch / epoch),
                ]);
                let mut cell = Value::table();
                cell.set("dataset", preset.name())
                    .set("topology", tname)
                    .set("workers", p)
                    .set("rapid_epoch_time", epoch)
                    .set("metis_epoch_time", metis_epoch);
                json.push(cell);
            }
            t.print();
        }
    }

    // --- straggler sensitivity: one slow worker on the flat fabric
    {
        let mut t = Table::new(
            "Fig 6b — straggler sensitivity (flat, P=4, worker 0 slowed)",
            &["slowdown", "Rapid epoch", "vs clean"],
        );
        let mut clean_epoch = 0.0;
        for factor in [1.0f64, 2.0, 4.0] {
            let mut cfg = paper_run(DatasetPreset::RedditSim, Engine::Rapid, 1000);
            cfg.num_workers = 4;
            if factor > 1.0 {
                cfg.fabric.straggler_worker = 0;
                cfg.fabric.straggler_factor = factor;
            }
            let r = coordinator::run(&cfg)?;
            let epoch = r.total_time / cfg.epochs as f64;
            if factor == 1.0 {
                clean_epoch = epoch;
            }
            t.row(&[
                format!("{factor:.0}x"),
                fmt_secs(epoch),
                format!("{:.2}x", epoch / clean_epoch),
            ]);
            let mut cell = Value::table();
            cell.set("dataset", "reddit-sim straggler")
                .set("straggler_factor", factor)
                .set("rapid_epoch_time", epoch);
            json.push(cell);
        }
        t.print();
    }

    // --- conformance gate: event-driven full mode vs trace, every cell
    let mut gate = Table::new(
        "Fig 6c — event-driven full mode vs trace (0.1× reddit-sim)",
        &["topology", "P", "remote rows", "full == trace", "makespan vs closed form"],
    );
    for (tname, topo) in topologies() {
        for &p in &WORKERS {
            let trace = coordinator::run(&identity_cfg(topo, p, ExecMode::Trace))?;
            let full = coordinator::run(&identity_cfg(topo, p, ExecMode::Full))?;
            assert_eq!(
                trace.total_remote_rows(),
                full.total_remote_rows(),
                "{tname} P={p}: full mode moved different rows than trace"
            );
            assert_eq!(trace.sync_remote_rows(), full.sync_remote_rows(), "{tname} P={p}");
            // Trace epoch times come from the closed-form pipeline_schedule;
            // full-mode times from the event-driven cluster runtime. On any
            // homogeneous (straggler-free) topology they must agree.
            let mut max_dt = 0.0f64;
            for f in &full.epochs {
                let t = trace
                    .epochs
                    .iter()
                    .find(|e| e.worker == f.worker && e.epoch == f.epoch)
                    .expect("matching trace epoch");
                max_dt = max_dt.max((t.epoch_time - f.epoch_time).abs());
            }
            assert!(
                max_dt < 1e-9,
                "{tname} P={p}: event vs closed-form drift {max_dt}"
            );
            gate.row(&[
                tname.into(),
                p.to_string(),
                trace.total_remote_rows().to_string(),
                "yes".into(),
                format!("{max_dt:.1e}"),
            ]);
            let mut cell = Value::table();
            cell.set("dataset", "reddit-sim-0.1x identity")
                .set("topology", tname)
                .set("workers", p)
                .set("remote_rows", trace.total_remote_rows())
                .set("event_vs_closed_form_drift", max_dt);
            json.push(cell);
        }
    }
    gate.print();

    // --- registry scenario engines: scaling cells + the same full==trace
    // gate, through the shared strategy pipeline (no engine-specific code
    // in this bench — `cfg.engine` is all that changes).
    let mut reg = Table::new(
        "Fig 6d — registry engines on the flat fabric (0.1× reddit-sim)",
        &["engine", "P", "epoch time", "remote rows", "full == trace"],
    );
    for engine in [Engine::FastSample, Engine::GreenWindow] {
        for &p in &[2u32, 4, 8] {
            let mut tcfg = identity_cfg(Topology::Flat, p, ExecMode::Trace);
            tcfg.engine = engine;
            let mut fcfg = identity_cfg(Topology::Flat, p, ExecMode::Full);
            fcfg.engine = engine;
            let trace = coordinator::run(&tcfg)?;
            let full = coordinator::run(&fcfg)?;
            assert_eq!(
                trace.total_remote_rows(),
                full.total_remote_rows(),
                "{} P={p}: full mode moved different rows than trace",
                engine.id()
            );
            let epoch = trace.total_time / tcfg.epochs as f64;
            reg.row(&[
                engine.id().into(),
                p.to_string(),
                fmt_secs(epoch),
                trace.total_remote_rows().to_string(),
                "yes".into(),
            ]);
            let mut cell = Value::table();
            cell.set("dataset", "reddit-sim-0.1x registry")
                .set("engine", engine.id())
                .set("workers", p)
                .set("epoch_time", epoch)
                .set("remote_rows", trace.total_remote_rows());
            json.push(cell);
        }
    }
    reg.print();

    // --- oversubscription × contention: shared-link queueing on the
    // two-tier spine. Gates (per ISSUE 4): with contention on, the
    // on-demand baseline's epoch time is monotonically non-decreasing in
    // the oversubscription factor and never beats the linear price; and
    // rapid's advantage over dgl-metis *widens* under contention (the
    // baseline's synchronous fetches queue on the spine, rapid's residual
    // misses mostly don't).
    {
        let cell = |engine: Engine, oversub: f64, contention: bool| -> rapidgnn::Result<f64> {
            let mut cfg = identity_cfg(
                Topology::TwoTier { racks: 2, oversubscription: oversub },
                4,
                ExecMode::Trace,
            );
            cfg.engine = engine;
            cfg.fabric.contention = contention;
            Ok(coordinator::run(&cfg)?.total_time / cfg.epochs as f64)
        };
        let mut t = Table::new(
            "Fig 6e — oversubscription × contention (two-tier, 0.1× reddit-sim, P=4)",
            &["oversub", "metis linear", "metis contended", "rapid contended", "metis/rapid"],
        );
        let mut prev_contended = 0.0f64;
        // (oversub, linear ratio, contended ratio)
        let mut ratios: Vec<(f64, f64, f64)> = Vec::new();
        for oversub in [1.0f64, 2.0, 4.0, 8.0, 16.0] {
            let metis_lin = cell(Engine::DglMetis, oversub, false)?;
            let rapid_lin = cell(Engine::Rapid, oversub, false)?;
            let metis_con = cell(Engine::DglMetis, oversub, true)?;
            let rapid_con = cell(Engine::Rapid, oversub, true)?;
            assert!(
                metis_con >= metis_lin - 1e-9,
                "oversub {oversub}: contended {metis_con} beat the linear price {metis_lin}"
            );
            assert!(
                rapid_con >= rapid_lin - 1e-9,
                "oversub {oversub}: contended rapid {rapid_con} beat linear {rapid_lin}"
            );
            assert!(
                metis_con >= prev_contended - 1e-9,
                "epoch time must be monotone in oversubscription: {metis_con} < {prev_contended}"
            );
            prev_contended = metis_con;
            ratios.push((oversub, metis_lin / rapid_lin, metis_con / rapid_con));
            t.row(&[
                format!("{oversub:.0}x"),
                fmt_secs(metis_lin),
                fmt_secs(metis_con),
                fmt_secs(rapid_con),
                format!("{:.2}x", metis_con / rapid_con),
            ]);
            let mut cellv = Value::table();
            cellv
                .set("dataset", "reddit-sim-0.1x contention")
                .set("oversubscription", oversub)
                .set("metis_epoch_linear", metis_lin)
                .set("metis_epoch_contended", metis_con)
                .set("rapid_epoch_contended", rapid_con);
            json.push(cellv);
        }
        t.print();
        let &(o, lin, con) = ratios.last().unwrap();
        assert!(
            con >= lin - 1e-9,
            "oversub {o}: contention must widen rapid's advantage ({con} !>= {lin})"
        );
    }

    // --- per-link utilization artifact: a contended fat-tree run's link
    // telemetry, with the conservation gate Σ busy ≥ Σ bytes / bandwidth.
    {
        let mut cfg = identity_cfg(Topology::FatTree { k: 4 }, 8, ExecMode::Trace);
        cfg.engine = Engine::DglMetis;
        cfg.fabric.contention = true;
        let r = coordinator::run(&cfg)?;
        assert!(!r.links.is_empty(), "contended run must report link telemetry");
        let busy: f64 = r.links.iter().map(|l| l.busy_sec).sum();
        let bytes: u64 = r.epochs.iter().map(|e| e.comm.bytes).sum();
        let floor = bytes as f64 / cfg.fabric.bandwidth_bytes_per_sec;
        assert!(busy >= floor - 1e-9, "Σ link busy {busy} < Σ bytes/bw {floor}");
        let links: Vec<Value> = r
            .links
            .iter()
            .map(|l| {
                let mut v = l.to_value();
                v.set("dataset", "reddit-sim-0.1x fat-tree contended")
                    .set("engine", "dgl-metis")
                    .set("workers", 8u32);
                v
            })
            .collect();
        std::fs::create_dir_all("bench_results").ok();
        std::fs::write(
            "bench_results/fig6_links.json",
            Value::Arr(links).to_json_pretty(),
        )?;
        println!(
            "per-link utilization for {} links written to bench_results/fig6_links.json",
            r.links.len()
        );
    }

    println!("paper: P=3 → 1.5-1.6x over P=2; P=4 → 1.7-2.1x (reddit)");
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig6.json", Value::Arr(json).to_json_pretty())?;
    Ok(())
}
