//! Figure 6 — throughput scaling with worker count, across interconnect
//! topologies.
//!
//! Paper: RapidGNN scales near-linearly; at P=3 speedup 1.5× (products) to
//! 1.6× (reddit) over P=2; at P=4, 1.7–2.1×. We sweep P ∈ {2,4,8,16}
//! (extending past the paper's 4-machine testbed) on all three datasets and
//! four fabric topologies (flat switch, 2-rack spine oversubscribed 8×,
//! ring, star/parameter-server — see `rust/src/sim/README.md` for how a
//! bench selects a topology: set `cfg.fabric.topology`).
//!
//! Conformance gate (per ISSUE 2): for every (topology × P) cell the
//! event-driven full mode must report *identical* `total_remote_rows()` to
//! trace mode, and on the homogeneous flat topology the event makespan must
//! match the closed-form `pipeline_schedule` within 1e-9 (the cluster
//! runtime's per-worker timelines equal the recurrence, so trace epoch time
//! doubles as the closed-form reference). The identity cells run on a
//! 0.1×-scaled reddit-sim so real full-mode SGD stays tractable at P=16.

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, ExecMode, RunConfig, Topology};
use rapidgnn::coordinator;
use rapidgnn::util::bench::{fmt_secs, Table};
use rapidgnn::util::bench_support::paper_run;
use rapidgnn::util::value::Value;

const WORKERS: [u32; 4] = [2, 4, 8, 16];

fn topologies() -> Vec<(&'static str, Topology)> {
    vec![
        ("flat", Topology::Flat),
        ("2tier-8x", Topology::TwoTier { racks: 2, oversubscription: 8.0 }),
        ("ring", Topology::Ring),
        ("star", Topology::Star { hub: 0 }),
    ]
}

/// Small full-mode-capable config for the per-cell trace/full identity gate.
fn identity_cfg(topo: Topology, workers: u32, mode: ExecMode) -> RunConfig {
    let mut cfg = RunConfig {
        dataset: DatasetConfig::preset(DatasetPreset::RedditSim, 0.1),
        engine: Engine::Rapid,
        num_workers: workers,
        batch_size: 64,
        epochs: 2,
        n_hot: 2_000,
        exec_mode: mode,
        ..Default::default()
    };
    cfg.dataset.train_fraction = 0.66;
    cfg.fabric.topology = topo;
    cfg
}

fn main() -> rapidgnn::Result<()> {
    let mut json = Vec::new();

    // --- scaling sweep: topology × P, trace mode, paper-scale datasets
    for preset in DatasetPreset::PAPER {
        for (tname, topo) in topologies() {
            let mut t = Table::new(
                &format!("Fig 6 — RapidGNN scaling on {} over {tname}", preset.name()),
                &["P", "epoch time", "speedup vs P=2", "DGL-METIS epoch", "Rapid vs METIS"],
            );
            let mut p2 = 0.0;
            for &p in &WORKERS {
                let mut cfg = paper_run(preset, Engine::Rapid, 1000);
                cfg.num_workers = p;
                cfg.fabric.topology = topo;
                let rapid = coordinator::run(&cfg)?;
                let mut bcfg = paper_run(preset, Engine::DglMetis, 1000);
                bcfg.num_workers = p;
                bcfg.fabric.topology = topo;
                let metis = coordinator::run(&bcfg)?;
                let epoch = rapid.total_time / cfg.epochs as f64;
                let metis_epoch = metis.total_time / bcfg.epochs as f64;
                if p == 2 {
                    p2 = epoch;
                }
                t.row(&[
                    p.to_string(),
                    fmt_secs(epoch),
                    format!("{:.2}x", p2 / epoch),
                    fmt_secs(metis_epoch),
                    format!("{:.2}x", metis_epoch / epoch),
                ]);
                let mut cell = Value::table();
                cell.set("dataset", preset.name())
                    .set("topology", tname)
                    .set("workers", p)
                    .set("rapid_epoch_time", epoch)
                    .set("metis_epoch_time", metis_epoch);
                json.push(cell);
            }
            t.print();
        }
    }

    // --- straggler sensitivity: one slow worker on the flat fabric
    {
        let mut t = Table::new(
            "Fig 6b — straggler sensitivity (flat, P=4, worker 0 slowed)",
            &["slowdown", "Rapid epoch", "vs clean"],
        );
        let mut clean_epoch = 0.0;
        for factor in [1.0f64, 2.0, 4.0] {
            let mut cfg = paper_run(DatasetPreset::RedditSim, Engine::Rapid, 1000);
            cfg.num_workers = 4;
            if factor > 1.0 {
                cfg.fabric.straggler_worker = 0;
                cfg.fabric.straggler_factor = factor;
            }
            let r = coordinator::run(&cfg)?;
            let epoch = r.total_time / cfg.epochs as f64;
            if factor == 1.0 {
                clean_epoch = epoch;
            }
            t.row(&[
                format!("{factor:.0}x"),
                fmt_secs(epoch),
                format!("{:.2}x", epoch / clean_epoch),
            ]);
            let mut cell = Value::table();
            cell.set("dataset", "reddit-sim straggler")
                .set("straggler_factor", factor)
                .set("rapid_epoch_time", epoch);
            json.push(cell);
        }
        t.print();
    }

    // --- conformance gate: event-driven full mode vs trace, every cell
    let mut gate = Table::new(
        "Fig 6c — event-driven full mode vs trace (0.1× reddit-sim)",
        &["topology", "P", "remote rows", "full == trace", "makespan vs closed form"],
    );
    for (tname, topo) in topologies() {
        for &p in &WORKERS {
            let trace = coordinator::run(&identity_cfg(topo, p, ExecMode::Trace))?;
            let full = coordinator::run(&identity_cfg(topo, p, ExecMode::Full))?;
            assert_eq!(
                trace.total_remote_rows(),
                full.total_remote_rows(),
                "{tname} P={p}: full mode moved different rows than trace"
            );
            assert_eq!(trace.sync_remote_rows(), full.sync_remote_rows(), "{tname} P={p}");
            // Trace epoch times come from the closed-form pipeline_schedule;
            // full-mode times from the event-driven cluster runtime. On any
            // homogeneous (straggler-free) topology they must agree.
            let mut max_dt = 0.0f64;
            for f in &full.epochs {
                let t = trace
                    .epochs
                    .iter()
                    .find(|e| e.worker == f.worker && e.epoch == f.epoch)
                    .expect("matching trace epoch");
                max_dt = max_dt.max((t.epoch_time - f.epoch_time).abs());
            }
            assert!(
                max_dt < 1e-9,
                "{tname} P={p}: event vs closed-form drift {max_dt}"
            );
            gate.row(&[
                tname.into(),
                p.to_string(),
                trace.total_remote_rows().to_string(),
                "yes".into(),
                format!("{max_dt:.1e}"),
            ]);
            let mut cell = Value::table();
            cell.set("dataset", "reddit-sim-0.1x identity")
                .set("topology", tname)
                .set("workers", p)
                .set("remote_rows", trace.total_remote_rows())
                .set("event_vs_closed_form_drift", max_dt);
            json.push(cell);
        }
    }
    gate.print();

    // --- registry scenario engines: scaling cells + the same full==trace
    // gate, through the shared strategy pipeline (no engine-specific code
    // in this bench — `cfg.engine` is all that changes).
    let mut reg = Table::new(
        "Fig 6d — registry engines on the flat fabric (0.1× reddit-sim)",
        &["engine", "P", "epoch time", "remote rows", "full == trace"],
    );
    for engine in [Engine::FastSample, Engine::GreenWindow] {
        for &p in &[2u32, 4, 8] {
            let mut tcfg = identity_cfg(Topology::Flat, p, ExecMode::Trace);
            tcfg.engine = engine;
            let mut fcfg = identity_cfg(Topology::Flat, p, ExecMode::Full);
            fcfg.engine = engine;
            let trace = coordinator::run(&tcfg)?;
            let full = coordinator::run(&fcfg)?;
            assert_eq!(
                trace.total_remote_rows(),
                full.total_remote_rows(),
                "{} P={p}: full mode moved different rows than trace",
                engine.id()
            );
            let epoch = trace.total_time / tcfg.epochs as f64;
            reg.row(&[
                engine.id().into(),
                p.to_string(),
                fmt_secs(epoch),
                trace.total_remote_rows().to_string(),
                "yes".into(),
            ]);
            let mut cell = Value::table();
            cell.set("dataset", "reddit-sim-0.1x registry")
                .set("engine", engine.id())
                .set("workers", p)
                .set("epoch_time", epoch)
                .set("remote_rows", trace.total_remote_rows());
            json.push(cell);
        }
    }
    reg.print();

    println!("paper: P=3 → 1.5-1.6x over P=2; P=4 → 1.7-2.1x (reddit)");
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig6.json", Value::Arr(json).to_json_pretty())?;
    Ok(())
}
