//! Figure 6 — throughput scaling with worker count.
//!
//! Paper: RapidGNN scales near-linearly; at P=3 speedup 1.5× (products) to
//! 1.6× (reddit) over P=2; at P=4, 1.7–2.1×. We sweep P ∈ {2,3,4,6,8}
//! (extending past the paper's 4-machine testbed) on all three datasets.

use rapidgnn::config::{DatasetPreset, Engine};
use rapidgnn::coordinator;
use rapidgnn::util::bench::{fmt_secs, Table};
use rapidgnn::util::bench_support::paper_run;
use rapidgnn::util::value::Value;

const WORKERS: [u32; 5] = [2, 3, 4, 6, 8];

fn main() -> rapidgnn::Result<()> {
    let mut json = Vec::new();
    for preset in DatasetPreset::PAPER {
        let mut t = Table::new(
            &format!("Fig 6 — RapidGNN scaling on {}", preset.name()),
            &["P", "epoch time", "speedup vs P=2", "DGL-METIS epoch", "Rapid vs METIS"],
        );
        let mut p2 = 0.0;
        for &p in &WORKERS {
            let mut cfg = paper_run(preset, Engine::Rapid, 1000);
            cfg.num_workers = p;
            let rapid = coordinator::run(&cfg)?;
            let mut bcfg = paper_run(preset, Engine::DglMetis, 1000);
            bcfg.num_workers = p;
            let metis = coordinator::run(&bcfg)?;
            let epoch = rapid.total_time / cfg.epochs as f64;
            let metis_epoch = metis.total_time / bcfg.epochs as f64;
            if p == 2 {
                p2 = epoch;
            }
            t.row(&[
                p.to_string(),
                fmt_secs(epoch),
                format!("{:.2}x", p2 / epoch),
                fmt_secs(metis_epoch),
                format!("{:.2}x", metis_epoch / epoch),
            ]);
            let mut cell = Value::table();
            cell.set("dataset", preset.name())
                .set("workers", p)
                .set("rapid_epoch_time", epoch)
                .set("metis_epoch_time", metis_epoch);
            json.push(cell);
        }
        t.print();
    }
    println!("paper: P=3 → 1.5-1.6x over P=2; P=4 → 1.7-2.1x (reddit)");
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig6.json", Value::Arr(json).to_json_pretty())?;
    Ok(())
}
