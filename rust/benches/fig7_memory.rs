//! Figure 7 — memory scaling with worker count, RapidGNN vs DGL-METIS.
//!
//! Paper: (a) GPU memory — RapidGNN consistently higher (device-resident
//! cache + staged prefetch buffers) but stable as P grows; (b) CPU memory —
//! RapidGNN tracks the baseline closely because precomputed schedules are
//! streamed from SSD rather than held in RAM.
//!
//! Device column = cache/staging bytes from the run report; host column =
//! per-worker feature shard + schedule working set (the dominant CPU terms).

use rapidgnn::config::{DatasetPreset, Engine};
use rapidgnn::coordinator;
use rapidgnn::util::bench::Table;
use rapidgnn::util::bench_support::paper_run;
use rapidgnn::util::value::Value;

const WORKERS: [u32; 3] = [2, 3, 4];

fn main() -> rapidgnn::Result<()> {
    let mut json = Vec::new();
    for preset in DatasetPreset::PAPER {
        let mut t = Table::new(
            &format!("Fig 7 — memory vs workers on {}", preset.name()),
            &["P", "Rapid GPU MB", "METIS GPU MB", "Rapid CPU MB", "METIS CPU MB"],
        );
        for &p in &WORKERS {
            let mut row = vec![p.to_string()];
            let mut cell = Value::table();
            cell.set("dataset", preset.name()).set("workers", p);
            let mut values = Vec::new();
            for engine in [Engine::Rapid, Engine::DglMetis] {
                let mut cfg = paper_run(preset, engine, 1000);
                cfg.num_workers = p;
                let report = coordinator::run(&cfg)?;
                // Per-worker host memory: the feature shard (graph features
                // split P ways) + the engine's schedule working set.
                let shard_bytes = cfg.dataset.num_nodes as u64 / p as u64
                    * cfg.dataset.feature_row_bytes();
                let host = shard_bytes + report.peak_host_bytes();
                values.push((report.peak_device_bytes(), host));
                cell.set(&format!("{}_gpu", engine.id()), report.peak_device_bytes())
                    .set(&format!("{}_cpu", engine.id()), host);
            }
            for (gpu, _) in &values {
                row.push(format!("{:.1}", *gpu as f64 / 1e6));
            }
            for (_, cpu) in &values {
                row.push(format!("{:.1}", *cpu as f64 / 1e6));
            }
            // interleave columns: rapid gpu, metis gpu, rapid cpu, metis cpu
            let r = vec![
                row[0].clone(),
                format!("{:.1}", values[0].0 as f64 / 1e6),
                format!("{:.1}", values[1].0 as f64 / 1e6),
                format!("{:.1}", values[0].1 as f64 / 1e6),
                format!("{:.1}", values[1].1 as f64 / 1e6),
            ];
            t.row(&r);
            json.push(cell);
        }
        t.print();
    }
    println!("expected shape: Rapid GPU > METIS GPU (cache) but stable in P; CPU columns track closely");
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig7.json", Value::Arr(json).to_json_pretty())?;
    Ok(())
}
