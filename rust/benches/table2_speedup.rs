//! Table 2 — step & network speedup of RapidGNN over DGL-METIS, DGL-Random,
//! and Dist-GCN: 3 datasets × batch {1000, 2000, 3000}.
//!
//! Paper result (means over all cells): step speedup 2.46× / 2.26× / 3.00×,
//! network speedup 12.70× / 9.70× / 15.39×. We reproduce the *shape*: who
//! wins, roughly by how much, and where (Reddit's heavier tail → biggest
//! wins).

use rapidgnn::config::{DatasetPreset, Engine};
use rapidgnn::coordinator;
use rapidgnn::metrics::RunReport;
use rapidgnn::util::bench::Table;
use rapidgnn::util::bench_support::{paper_run, PAPER_BATCHES};
use rapidgnn::util::value::Value;

fn main() -> rapidgnn::Result<()> {
    let mut t = Table::new(
        "Table 2 — Speedup of RapidGNN over DGL-METIS, DGL-Random, GCN",
        &["dataset", "batch", "step vMETIS", "step vRandom", "step vGCN",
          "net vMETIS", "net vRandom", "net vGCN"],
    );
    let mut json = Vec::new();
    let (mut step_sums, mut net_sums) = ([0.0f64; 3], [0.0f64; 3]);
    let mut cells = 0u32;

    for preset in DatasetPreset::PAPER {
        for batch in PAPER_BATCHES {
            let mut reports: Vec<RunReport> = Vec::new();
            for engine in Engine::ALL {
                let cfg = paper_run(preset, engine, batch);
                reports.push(coordinator::run(&cfg)?);
            }
            let rapid = &reports[0];
            let step = |r: &RunReport| r.mean_step_time();
            let net = |r: &RunReport| r.mean_net_time_per_step();
            let mut row = vec![preset.name().to_string(), batch.to_string()];
            let mut cell = Value::table();
            cell.set("dataset", preset.name()).set("batch", batch);
            let mut steps_x = Vec::new();
            let mut nets_x = Vec::new();
            for (i, baseline) in reports[1..].iter().enumerate() {
                let s = step(baseline) / step(rapid);
                step_sums[i] += s;
                steps_x.push(s);
                cell.set(&format!("step_x_{}", Engine::ALL[i + 1].id()), s);
            }
            for (i, baseline) in reports[1..].iter().enumerate() {
                let x = net(baseline) / net(rapid).max(1e-12);
                net_sums[i] += x;
                nets_x.push(x);
                cell.set(&format!("net_x_{}", Engine::ALL[i + 1].id()), x);
            }
            for s in steps_x {
                row.push(format!("{s:.2}"));
            }
            for x in nets_x {
                row.push(format!("{x:.2}"));
            }
            cells += 1;
            t.row(&row);
            json.push(cell);
        }
    }
    let n = cells as f64;
    t.row(&[
        "Average".into(),
        "-".into(),
        format!("{:.2}", step_sums[0] / n),
        format!("{:.2}", step_sums[1] / n),
        format!("{:.2}", step_sums[2] / n),
        format!("{:.2}", net_sums[0] / n),
        format!("{:.2}", net_sums[1] / n),
        format!("{:.2}", net_sums[2] / n),
    ]);
    t.print();
    println!("paper averages: step 2.46/2.26/3.00 vs METIS/Random/GCN; net 12.70/9.70/15.39");
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/table2.json", Value::Arr(json).to_json_pretty())?;
    Ok(())
}
