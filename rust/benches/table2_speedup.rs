//! Table 2 — step & network speedup of RapidGNN over DGL-METIS, DGL-Random,
//! and Dist-GCN: 3 datasets × batch {1000, 2000, 3000}.
//!
//! Paper result (means over all cells): step speedup 2.46× / 2.26× / 3.00×,
//! network speedup 12.70× / 9.70× / 15.39×. We reproduce the *shape*: who
//! wins, roughly by how much, and where (Reddit's heavier tail → biggest
//! wins).

use rapidgnn::config::{DatasetPreset, Engine};
use rapidgnn::coordinator;
use rapidgnn::metrics::RunReport;
use rapidgnn::util::bench::Table;
use rapidgnn::util::bench_support::{paper_run, PAPER_BATCHES};
use rapidgnn::util::value::Value;

fn main() -> rapidgnn::Result<()> {
    let mut t = Table::new(
        "Table 2 — Speedup of RapidGNN over DGL-METIS, DGL-Random, GCN",
        &["dataset", "batch", "step vMETIS", "step vRandom", "step vGCN",
          "net vMETIS", "net vRandom", "net vGCN"],
    );
    let mut json = Vec::new();
    let (mut step_sums, mut net_sums) = ([0.0f64; 3], [0.0f64; 3]);
    let mut cells = 0u32;

    for preset in DatasetPreset::PAPER {
        for batch in PAPER_BATCHES {
            let mut reports: Vec<RunReport> = Vec::new();
            for engine in Engine::ALL {
                let cfg = paper_run(preset, engine, batch);
                reports.push(coordinator::run(&cfg)?);
            }
            let rapid = &reports[0];
            let step = |r: &RunReport| r.mean_step_time();
            let net = |r: &RunReport| r.mean_net_time_per_step();
            let mut row = vec![preset.name().to_string(), batch.to_string()];
            let mut cell = Value::table();
            cell.set("dataset", preset.name()).set("batch", batch);
            let mut steps_x = Vec::new();
            let mut nets_x = Vec::new();
            for (i, baseline) in reports[1..].iter().enumerate() {
                let s = step(baseline) / step(rapid);
                step_sums[i] += s;
                steps_x.push(s);
                cell.set(&format!("step_x_{}", Engine::ALL[i + 1].id()), s);
            }
            for (i, baseline) in reports[1..].iter().enumerate() {
                let x = net(baseline) / net(rapid).max(1e-12);
                net_sums[i] += x;
                nets_x.push(x);
                cell.set(&format!("net_x_{}", Engine::ALL[i + 1].id()), x);
            }
            for s in steps_x {
                row.push(format!("{s:.2}"));
            }
            for x in nets_x {
                row.push(format!("{x:.2}"));
            }
            cells += 1;
            t.row(&row);
            json.push(cell);
        }
    }
    let n = cells as f64;
    t.row(&[
        "Average".into(),
        "-".into(),
        format!("{:.2}", step_sums[0] / n),
        format!("{:.2}", step_sums[1] / n),
        format!("{:.2}", step_sums[2] / n),
        format!("{:.2}", net_sums[0] / n),
        format!("{:.2}", net_sums[1] / n),
        format!("{:.2}", net_sums[2] / n),
    ]);
    t.print();
    println!("paper averages: step 2.46/2.26/3.00 vs METIS/Random/GCN; net 12.70/9.70/15.39");

    // --- registry scenario engines: one cell each on products-sim/1000.
    // fast-sample amortizes the offline pass (setup ÷ vs rapid at equal
    // per-step cost); green-window trades step latency for fewer RPCs than
    // its per-batch twin dgl-metis.
    let mut extra = Table::new(
        "Registry engines — scenario cells (products-sim, batch 1000)",
        &["engine", "step time", "setup", "sync RPCs", "net/step"],
    );
    let rapid = coordinator::run(&paper_run(DatasetPreset::ProductsSim, Engine::Rapid, 1000))?;
    let metis = coordinator::run(&paper_run(DatasetPreset::ProductsSim, Engine::DglMetis, 1000))?;
    let mut fs_cfg = paper_run(DatasetPreset::ProductsSim, Engine::FastSample, 1000);
    fs_cfg.engine_params.resample_period = 2;
    let fast = coordinator::run(&fs_cfg)?;
    let green =
        coordinator::run(&paper_run(DatasetPreset::ProductsSim, Engine::GreenWindow, 1000))?;
    let rpcs = |r: &RunReport| -> u64 { r.epochs.iter().map(|e| e.comm.sync_pulls).sum() };
    for r in [&rapid, &metis, &fast, &green] {
        extra.row(&[
            r.engine.clone(),
            rapidgnn::util::bench::fmt_secs(r.mean_step_time()),
            rapidgnn::util::bench::fmt_secs(r.setup_time),
            rpcs(r).to_string(),
            rapidgnn::util::bench::fmt_secs(r.mean_net_time_per_step()),
        ]);
        let mut cell = Value::table();
        cell.set("dataset", "products-sim registry cell")
            .set("engine", r.engine.as_str())
            .set("mean_step_time", r.mean_step_time())
            .set("setup_time", r.setup_time)
            .set("sync_rpcs", rpcs(r));
        json.push(cell);
    }
    extra.print();
    assert!(fast.setup_time < rapid.setup_time, "fast-sample must amortize precompute");
    assert!(rpcs(&green) < rpcs(&metis), "green-window must cut RPC count");

    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/table2.json", Value::Arr(json).to_json_pretty())?;
    Ok(())
}
