//! Figure 4 — mean data transferred per training step, RapidGNN vs
//! DGL-METIS, across the three datasets and batch sizes 1000/2000/3000,
//! plus the compression cells: quant-pull (int8 feature wire codec) against
//! rapid's uncompressed pulls.
//!
//! Paper: OGBN-Papers 1.5/3.1/4.6 MB vs METIS 4.3/8.3/12.0 (≈2.6–2.8×);
//! Reddit 0.3/0.6/0.9 MB vs 6.8/10.0/14.0 (15–23×); Products 2.0/3.8/5.4 vs
//! 4.8/8.8/12.1 (2.2–2.5×). Expected shape: RapidGNN always lower, Reddit's
//! reduction largest (heaviest tail × widest rows). The int8 cells stack a
//! further ≈3.7× payload cut (d + 8·⌈d/128⌉ vs 4d bytes per row) on top of
//! whatever rows the engine already avoided moving.

use rapidgnn::config::{DatasetPreset, Engine};
use rapidgnn::coordinator;
use rapidgnn::util::bench::{fmt_bytes, Table};
use rapidgnn::util::bench_support::{paper_run, PAPER_BATCHES};
use rapidgnn::util::value::Value;

fn main() -> rapidgnn::Result<()> {
    let mut t = Table::new(
        "Fig 4 — mean data transfer per step: RapidGNN vs DGL-METIS vs quant-pull",
        &[
            "dataset",
            "batch",
            "Rapid/step",
            "Rapid+cache/step",
            "METIS/step",
            "reduction",
            "int8/step",
            "int8 ratio",
        ],
    );
    let mut json = Vec::new();
    for preset in DatasetPreset::PAPER {
        for batch in PAPER_BATCHES {
            let rapid = coordinator::run(&paper_run(preset, Engine::Rapid, batch))?;
            let metis = coordinator::run(&paper_run(preset, Engine::DglMetis, batch))?;
            let quant = coordinator::run(&paper_run(preset, Engine::QuantPull, batch))?;
            let steps: u64 = rapid.epochs.iter().map(|e| e.steps as u64).sum();
            let row_bytes = paper_run(preset, Engine::Rapid, batch)
                .dataset
                .feature_row_bytes();
            // Training-path bytes (SyncPull misses) — the paper's Fig-4
            // metric; cache-build VectorPulls amortize off the step path.
            let r_sync = rapid.sync_remote_rows() as f64 * row_bytes as f64 / steps as f64;
            let r_total = rapid.mean_bytes_per_step();
            let m = metis.mean_bytes_per_step();
            // Compression gates: the codec must never change WHICH rows move,
            // and the priced feature payload (per-block headers included)
            // must shrink ≥ 3.5x — the int8 budget at every paper width.
            assert_eq!(
                quant.total_remote_rows(),
                rapid.total_remote_rows(),
                "{}: quant-pull changed remote row movement",
                preset.name()
            );
            let comp = quant
                .compression
                .as_ref()
                .expect("quant-pull must report compression telemetry");
            assert!(
                comp.effective_compression_ratio >= 3.5,
                "{}: int8 payload ratio {:.2} below the 3.5x gate",
                preset.name(),
                comp.effective_compression_ratio
            );
            let q_total = quant.mean_bytes_per_step();
            t.row(&[
                preset.name().into(),
                batch.to_string(),
                fmt_bytes(r_sync),
                fmt_bytes(r_total),
                fmt_bytes(m),
                format!("{:.1}x", m / r_sync.max(1.0)),
                fmt_bytes(q_total),
                format!("{:.2}x", r_total / q_total.max(1.0)),
            ]);
            let mut cell = Value::table();
            cell.set("dataset", preset.name())
                .set("batch", batch)
                .set("rapid_sync_bytes_per_step", r_sync)
                .set("rapid_total_bytes_per_step", r_total)
                .set("metis_bytes_per_step", m)
                .set("quant_pull_bytes_per_step", q_total)
                .set("quant_payload_ratio", comp.effective_compression_ratio)
                .set("quant_bytes_saved", comp.bytes_saved);
            json.push(cell);
        }
    }
    t.print();
    println!("paper reductions: Papers ~2.6-2.8x, Products ~2.2-2.5x, Reddit ~15-23x");
    println!("int8 payload gate: >=3.5x on every dataset, remote rows codec-invariant");
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/fig4.json", Value::Arr(json).to_json_pretty())?;
    Ok(())
}
