//! Hot-path microbenchmarks (the §Perf instrument panel).
//!
//! Measures the components on RapidGNN's critical and background paths:
//! k-hop sampling, schedule streaming, cache lookup, feature gather, MPMC
//! queue throughput, the pipeline-schedule recurrence, the host matmul, and
//! (when artifacts exist) PJRT step latency.

use rapidgnn::cache::{top_hot, CacheBuffer, DoubleBufferCache};
use rapidgnn::config::{DatasetConfig, DatasetPreset, RunConfig};
use rapidgnn::coordinator::RunContext;
use rapidgnn::graph::build_dataset;
use rapidgnn::sampler::{enumerate_epoch, sample_input_nodes, Fanout};
use rapidgnn::sim::{pipeline_schedule, PipelineStep};
use rapidgnn::trainer::Mat;
use rapidgnn::util::bench::{fmt_secs, time_until, Table};

fn main() -> rapidgnn::Result<()> {
    let mut t = Table::new("Microbenchmarks", &["path", "per-op", "throughput"]);

    // --- k-hop sampling (products-sim shape) ---
    let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::ProductsSim, 0.3), false);
    let seeds: Vec<u32> = ds.train_nodes.iter().take(1000).copied().collect();
    let fanouts = [Fanout::Sample(10), Fanout::Sample(25)];
    let mut n_sampled = 0usize;
    let (iters, _, per) = time_until(1.0, || {
        let ids = sample_input_nodes(&ds.graph, &seeds, &fanouts, 42);
        n_sampled = ids.len();
        std::hint::black_box(&ids);
    });
    t.row(&[
        format!("k-hop sample (batch 1000, [10,25], {n_sampled} ids)"),
        fmt_secs(per),
        format!("{:.1}M ids/s", n_sampled as f64 * iters as f64 / 1e6 / (per * iters as f64)),
    ]);

    // --- schedule enumeration + streaming round trip ---
    let part = rapidgnn::partition::metis_like(&ds.graph, 4, 0);
    let shard: Vec<u32> = ds.train_nodes.iter().copied().filter(|&v| part.is_local(0, v)).collect();
    let (_, _, per) = time_until(1.0, || {
        let s = enumerate_epoch(&ds.graph, &part, &shard, &fanouts, 1000, 42, 0, 0);
        std::hint::black_box(s.batches.len());
    });
    t.row(&["enumerate_epoch (per epoch/worker)".into(), fmt_secs(per), "-".into()]);

    // --- cache lookup ---
    let sched = enumerate_epoch(&ds.graph, &part, &shard, &fanouts, 1000, 42, 0, 0);
    let hot = top_hot(&sched.batches, 10_000);
    let mut cache = DoubleBufferCache::default();
    cache.install_steady(CacheBuffer::new(&hot, Vec::new(), 100));
    let remote: Vec<u32> = sched.batches[0].remote_nodes().collect();
    let (mut h, mut m) = (Vec::new(), Vec::new());
    let (_, _, per) = time_until(0.5, || {
        cache.split_hits(&remote, &mut h, &mut m);
    });
    t.row(&[
        format!("cache split_hits ({} ids)", remote.len()),
        fmt_secs(per),
        format!("{:.1}M lookups/s", remote.len() as f64 / per / 1e6),
    ]);

    // --- feature gather (kvstore full mode) ---
    let ds_f = build_dataset(&DatasetConfig::preset(DatasetPreset::ProductsSim, 0.05), true);
    let part_f = std::sync::Arc::new(rapidgnn::partition::metis_like(&ds_f.graph, 2, 0));
    let kv = rapidgnn::kvstore::KvStore::new(
        &ds_f,
        part_f,
        rapidgnn::net::NetFabric::new(Default::default()),
    );
    let ids: Vec<u32> = (0..5_000).map(|i| (i * 7) % ds_f.graph.num_nodes()).collect();
    let mut out = Vec::new();
    let mut stats = Default::default();
    let (_, _, per) = time_until(0.5, || {
        kv.pull(rapidgnn::kvstore::PullRequest::sync(0, &ids), Some(&mut out), &mut stats);
    });
    let gb = (ids.len() * kv.feature_dim() * 4) as f64 / per / 1e9;
    t.row(&[
        format!("feature gather ({} rows × d=100)", ids.len()),
        fmt_secs(per),
        format!("{gb:.2} GB/s"),
    ]);

    // --- MPMC ring ---
    let (_, _, per) = time_until(0.5, || {
        let (tx, rx) = rapidgnn::util::mpmc::bounded::<u64>(16);
        #[allow(clippy::disallowed_methods)] // bench measures the raw ring, one ad-hoc producer
        let h = std::thread::spawn(move || {
            for i in 0..10_000u64 {
                tx.send(i).unwrap();
            }
        });
        let mut n = 0;
        while rx.recv().is_ok() {
            n += 1;
        }
        h.join().unwrap();
        std::hint::black_box(n);
    });
    t.row(&[
        "MPMC ring (10k msgs, 1P/1C)".into(),
        fmt_secs(per),
        format!("{:.2}M msg/s", 10_000.0 / per / 1e6),
    ]);

    // --- pipeline schedule recurrence ---
    let steps: Vec<PipelineStep> = (0..10_000)
        .map(|i| PipelineStep { stage: (i % 7) as f64 * 1e-4, consume: 1e-3 })
        .collect();
    let (_, _, per) = time_until(0.5, || {
        std::hint::black_box(pipeline_schedule(&steps, 4).total);
    });
    t.row(&[
        "pipeline_schedule (10k steps)".into(),
        fmt_secs(per),
        format!("{:.1}M steps/s", 10_000.0 / per / 1e6),
    ]);

    // --- host matmul (trainer hot loop) ---
    let a = Mat::init(2048, 100, 1);
    let b = Mat::init(100, 64, 2);
    let (_, _, per) = time_until(1.0, || {
        std::hint::black_box(a.matmul(&b).data[0]);
    });
    let gflops = 2.0 * 2048.0 * 100.0 * 64.0 / per / 1e9;
    t.row(&[
        "host matmul 2048x100x64".into(),
        fmt_secs(per),
        format!("{gflops:.2} GFLOP/s"),
    ]);

    // --- PJRT step latency (needs artifacts) ---
    let mut cfg = RunConfig::default();
    cfg.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
    let ctx = RunContext::build(&cfg)?;
    match rapidgnn::runtime::find_artifact(&rapidgnn::runtime::artifacts_dir(), &ctx) {
        Ok(meta) => {
            use rapidgnn::sampler::sample_blocks;
            use rapidgnn::trainer::{batch_labels, TrainStep};
            let caps = (meta.b_cap, meta.n1_cap, meta.n0_cap);
            let mut trainer = rapidgnn::runtime::PjrtTrainer::load(meta, 42)?;
            let ds = build_dataset(&cfg.dataset, true);
            let seeds: Vec<u32> = ds.train_nodes.iter().take(64).copied().collect();
            let fo: Vec<Fanout> = cfg.fanout.iter().map(|&f| Fanout::Sample(f)).collect();
            let batch = sample_blocks(&ds.graph, &seeds, &fo, 1);
            let d = ds.config.feature_dim as usize;
            let mut x0 = Mat::zeros(batch.node_layers[0].len(), d);
            for (i, &v) in batch.node_layers[0].iter().enumerate() {
                x0.row_mut(i).copy_from_slice(ds.feature_row(v));
            }
            let labels = batch_labels(&ds, &batch);
            let (_, _, per) = time_until(2.0, || {
                std::hint::black_box(trainer.step(&x0, &batch, &labels, 0.05).loss);
            });
            t.row(&[
                format!("PJRT train step (tiny artifact, caps {caps:?})"),
                fmt_secs(per),
                "-".into(),
            ]);
        }
        Err(_) => {
            t.row(&["PJRT train step".into(), "skipped (no artifacts)".into(), "-".into()]);
        }
    }

    t.print();
    Ok(())
}
