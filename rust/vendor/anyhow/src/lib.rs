//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! This build environment is fully offline (see the workspace's
//! `src/util/mod.rs`), so the real crates.io `anyhow` cannot be fetched.
//! This vendored shim implements exactly the subset the workspace uses —
//! [`Error`], [`Result`], [`Context`], and the `anyhow!` / `bail!` /
//! `ensure!` macros — with matching semantics: contexts stack
//! outermost-first, `Display` shows the outermost message, `{:#}` joins the
//! chain with `": "`, and `{:?}` renders the full cause chain.

use std::fmt;

/// An error: an outermost message plus its cause chain (outermost first).
///
/// Like `anyhow::Error`, this type deliberately does **not** implement
/// `std::error::Error` so that the blanket `From<E: std::error::Error>`
/// conversion (what makes `?` work on any std error) stays coherent.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap the error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting the error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, like `anyhow::Context`.
///
/// Implemented for `Result<T, E>` over std errors and for `Option<T>`
/// (where the context becomes the error message). Call sites that need
/// context on an already-`anyhow` `Result` use `map_err` +
/// [`Error::context`] instead, keeping the impls trivially coherent.
pub trait Context<T>: Sized {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_shows_outermost_message_only() {
        let e: Error = Err::<(), _>(io_err()).context("open config").unwrap_err();
        assert_eq!(e.to_string(), "open config");
        assert_eq!(format!("{e:#}"), "open config: missing");
    }

    #[test]
    fn debug_renders_cause_chain() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| format!("open {}", "x.toml"))
            .unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("open x.toml"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("missing"));
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
        fn fails(n: u32) -> Result<u32> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("three is right out");
            }
            Ok(n)
        }
        assert_eq!(fails(2).unwrap(), 2);
        assert_eq!(fails(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(fails(99).unwrap_err().to_string(), "n too big: 99");
        assert_eq!(anyhow!("x = {}", 7).to_string(), "x = 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(read().is_err());
    }
}
