//! Tier-1 harness for `rapidgnn-lint`: shells the xtask binary so contract
//! drift fails plain `cargo test`, and pins each rule class against the
//! seeded-violation fixtures under `tests/fixtures/lint/`.

use std::process::Command;

/// Run the lint binary with `args`; returns (exit-ok, stdout).
fn run_lint(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_rapidgnn-lint"))
        .args(args)
        .output()
        .expect("spawn rapidgnn-lint");
    let mut text = String::from_utf8_lossy(&out.stdout).into_owned();
    text.push_str(&String::from_utf8_lossy(&out.stderr));
    (out.status.success(), text)
}

fn fixture_root(name: &str) -> String {
    format!("{}/tests/fixtures/lint/{name}", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn repo_at_head_is_clean() {
    let (ok, out) = run_lint(&["lint"]);
    assert!(ok, "determinism contracts violated at HEAD:\n{out}");
    assert!(out.contains("0 violation(s)"), "unexpected summary:\n{out}");
}

#[test]
fn every_rule_class_fires_on_its_seeded_fixture() {
    let root = fixture_root("bad");
    let (ok, out) = run_lint(&["lint", "--root", &root]);
    assert!(!ok, "seeded violations must fail the scan:\n{out}");
    for rule in [
        "priced-recovery",
        "unordered-collections",
        "wall-clock",
        "thread-spawn",
        "unordered-float-reduce",
        "module-docs",
        "trace-sink",
        "charge-ladder",
    ] {
        assert!(out.contains(&format!("[{rule}]")), "rule {rule} did not fire:\n{out}");
    }
    // The doc-comment mention of charge_rpc in the fixture must not fire:
    // only the two real calls do.
    let recovery_hits =
        out.lines().filter(|l| l.contains("[priced-recovery]")).count();
    assert_eq!(recovery_hits, 2, "comment text must not trip priced-recovery:\n{out}");
    // charge-ladder: two calls in puller.rs plus the recovery fixture's two
    // charge_* calls (which legitimately trip both rules); doc comments never.
    let ladder_hits = out.lines().filter(|l| l.contains("[charge-ladder]")).count();
    assert_eq!(ladder_hits, 4, "comment text must not trip charge-ladder:\n{out}");
}

#[test]
fn well_formed_markers_suppress_their_rule() {
    let root = fixture_root("good");
    let (ok, out) = run_lint(&["lint", "--root", &root]);
    assert!(ok, "annotated exceptions must pass:\n{out}");
}

#[test]
fn malformed_markers_are_violations() {
    let root = fixture_root("badmarker");
    let (ok, out) = run_lint(&["lint", "--root", &root]);
    assert!(!ok, "marker without justification must fail:\n{out}");
    let hits = out.lines().filter(|l| l.contains("[marker-justification]")).count();
    assert_eq!(hits, 2, "expected the unjustified and unknown-rule markers:\n{out}");
}

#[test]
fn unknown_arguments_are_usage_errors() {
    let (ok, out) = run_lint(&["lint", "--frobnicate"]);
    assert!(!ok, "unknown flags must not silently pass:\n{out}");
}
