//! Cross-module integration tests: engines under stress, failure injection,
//! and end-to-end invariants that unit tests can't see.

use rapidgnn::cache::{top_hot, CacheBuffer, DoubleBufferCache};
use rapidgnn::config::{
    DatasetConfig, DatasetPreset, Engine, ExecMode, FabricConfig, RunConfig, Topology,
};
use rapidgnn::coordinator::{self, RunContext};
use rapidgnn::graph::build_dataset;
use rapidgnn::kvstore::KvStore;
use rapidgnn::net::NetFabric;
use rapidgnn::partition::metis_like;
use rapidgnn::prefetch::Prefetcher;
use rapidgnn::sampler::{enumerate_epoch, Fanout};
use std::sync::{Arc, Mutex};

fn tiny_cfg(engine: Engine) -> RunConfig {
    let mut c = RunConfig::default();
    c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
    c.engine = engine;
    c.epochs = 3;
    c.n_hot = 400;
    c
}

#[test]
fn trace_and_full_mode_agree_on_communication_for_every_registered_engine() {
    // The trace path (metadata-only staging) and the full path (real feature
    // movement + shared-model SGD on the cluster runtime) must count
    // identical remote traffic — for every engine the registry knows,
    // including the registry-only `fast-sample` and `green-window`.
    for engine in coordinator::EngineRegistry::global().engines() {
        let mut trace = tiny_cfg(engine);
        trace.batch_size = 64;
        let mut full = trace.clone();
        full.exec_mode = ExecMode::Full;
        let rt = coordinator::run(&trace).unwrap();
        let rf = coordinator::run(&full).unwrap();
        assert_eq!(
            rt.total_remote_rows(),
            rf.total_remote_rows(),
            "{}: full mode moved different rows than trace",
            engine.id()
        );
        assert_eq!(rt.sync_remote_rows(), rf.sync_remote_rows(), "{}", engine.id());
        // cache behaviour identical too
        assert!(
            (rt.cache_hit_rate() - rf.cache_hit_rate()).abs() < 1e-12,
            "{}",
            engine.id()
        );
    }
}

#[test]
fn rapid_minimizes_remote_rows_across_the_registry() {
    // Table-2 style, over the *open* engine set: RapidGNN moves the fewest
    // remote rows of any registered engine. fast-sample is run at
    // resample_period = 1 and adaptive-cache with its controller disabled
    // (resize_period = 0) — both provably coincide with rapid there; tuned
    // away from those settings they trade freshness or cache capacity for
    // traffic, which would make this minimality assertion vacuous rather
    // than false.
    let mut rows_by_engine = Vec::new();
    for engine in coordinator::EngineRegistry::global().engines() {
        let mut cfg = tiny_cfg(engine);
        cfg.engine_params.resample_period = 1;
        cfg.engine_params.resize_period = 0;
        let r = coordinator::run(&cfg).unwrap();
        rows_by_engine.push((engine, r.total_remote_rows()));
    }
    let rapid_rows = rows_by_engine
        .iter()
        .find(|(e, _)| *e == Engine::Rapid)
        .expect("rapid registered")
        .1;
    // quant-pull and grad-topk compress bytes (and gradients), never rows —
    // their remote_rows match rapid's exactly.
    let rapid_equivalent = [
        Engine::Rapid,
        Engine::FastSample,
        Engine::AdaptiveCache,
        Engine::QuantPull,
        Engine::GradTopk,
    ];
    for (engine, rows) in &rows_by_engine {
        assert!(
            rapid_rows <= *rows,
            "{}: rapid {} !<= {}",
            engine.id(),
            rapid_rows,
            rows
        );
        if !rapid_equivalent.contains(engine) {
            assert!(rapid_rows < *rows, "{}: strict for on-demand engines", engine.id());
        }
    }
}

#[test]
fn green_window_cuts_rpc_count_not_rows_vs_dgl_metis() {
    // The GreenGNN trade on tiny: merged fetch windows issue strictly fewer
    // sync RPCs than per-batch fetching while moving exactly the same rows.
    let green = coordinator::run(&tiny_cfg(Engine::GreenWindow)).unwrap();
    let metis = coordinator::run(&tiny_cfg(Engine::DglMetis)).unwrap();
    assert_eq!(green.total_remote_rows(), metis.total_remote_rows());
    let rpcs = |r: &rapidgnn::metrics::RunReport| -> u64 {
        r.epochs.iter().map(|e| e.comm.sync_pulls).sum()
    };
    assert!(
        rpcs(&green) < rpcs(&metis),
        "green-window {} RPCs !< dgl-metis {}",
        rpcs(&green),
        rpcs(&metis)
    );
    assert!(green.total_time < metis.total_time, "fewer latencies → faster epochs");
}

#[test]
fn network_failures_slow_but_do_not_break() {
    // Inject a retry on every 5th RPC: engines must complete with identical
    // data movement and strictly more simulated network time.
    let cfg = tiny_cfg(Engine::Rapid);
    let clean_ctx = RunContext::build(&cfg).unwrap();
    let clean = coordinator::run_with_context(&clean_ctx).unwrap();

    // rebuild with a faulty fabric: swap in via a custom context
    let ds = Arc::new(build_dataset(&cfg.dataset, false));
    let part = Arc::new(metis_like(&ds.graph, cfg.num_workers, cfg.base_seed));
    let fabric = NetFabric::new(cfg.fabric.clone()).with_failures(5);
    let kv = Arc::new(KvStore::new(&ds, part.clone(), fabric));
    let shard: Vec<u32> = ds
        .train_nodes
        .iter()
        .copied()
        .filter(|&v| part.is_local(0, v))
        .collect();
    // drive one epoch of staging directly against the faulty store
    let sched = enumerate_epoch(
        &ds.graph,
        &part,
        &shard,
        &[Fanout::Sample(10), Fanout::Sample(25)],
        cfg.batch_size,
        cfg.base_seed,
        0,
        0,
    );
    let hot = top_hot(&sched.batches, cfg.n_hot);
    let cache = Arc::new(Mutex::new({
        let mut c = DoubleBufferCache::default();
        c.install_steady(CacheBuffer::new(&hot, Vec::new(), kv.feature_dim()));
        c
    }));
    let mut faulty_stats = rapidgnn::metrics::CommStats::default();
    for meta in sched.batches.iter().cloned() {
        rapidgnn::prefetch::stage_batch(&kv, &cache, meta, 0, false, &mut faulty_stats);
    }
    // identical rows moved, strictly more time than the clean epoch-0 fetch
    let clean_epoch0_rows: u64 = clean
        .epochs
        .iter()
        .filter(|e| e.epoch == 0 && e.worker == 0)
        .map(|e| e.comm.remote_rows - e.comm.vector_rows)
        .sum();
    assert_eq!(faulty_stats.remote_rows, clean_epoch0_rows);
    assert!(faulty_stats.net_time > 0.0);
}

#[test]
fn per_link_loss_rates_leave_data_movement_unchanged() {
    // The promoted failure path: per-link loss rates slow runs down but must
    // not change what either engine fetches — Rapid and the baseline move
    // exactly the same remote rows with and without injected failures.
    for engine in [Engine::Rapid, Engine::DglMetis] {
        let clean_cfg = tiny_cfg(engine);
        let mut faulty_cfg = tiny_cfg(engine);
        faulty_cfg.fabric.loss_rate = 0.2; // every 5th RPC per link retried
        let clean_ctx = RunContext::build(&clean_cfg).unwrap();
        let faulty_ctx = RunContext::build(&faulty_cfg).unwrap();
        let clean = coordinator::run_with_context(&clean_ctx).unwrap();
        let faulty = coordinator::run_with_context(&faulty_ctx).unwrap();
        assert_eq!(
            clean.total_remote_rows(),
            faulty.total_remote_rows(),
            "{}: loss injection must not change data movement",
            engine.name()
        );
        assert_eq!(clean.sync_remote_rows(), faulty.sync_remote_rows());
        assert_eq!(faulty_ctx.fabric.total_rpcs(), clean_ctx.fabric.total_rpcs());
        assert!(faulty_ctx.fabric.total_retries() > 0, "retries were injected");
        assert_eq!(clean_ctx.fabric.total_retries(), 0);
        assert!(
            faulty.total_time > clean.total_time - 1e-12,
            "{}: failures cannot speed a run up",
            engine.name()
        );
    }
    // The serial baseline pays every retry on the critical path.
    let mut faulty_cfg = tiny_cfg(Engine::DglMetis);
    faulty_cfg.fabric.loss_rate = 0.5;
    let clean = coordinator::run(&tiny_cfg(Engine::DglMetis)).unwrap();
    let faulty = coordinator::run(&faulty_cfg).unwrap();
    assert!(
        faulty.total_time > clean.total_time,
        "baseline with 50% loss: {} !> {}",
        faulty.total_time,
        clean.total_time
    );
}

#[test]
fn topology_changes_time_but_not_rows() {
    // The topology axis prices links differently; it must never change which
    // rows move. An 8×-oversubscribed spine must slow the on-demand baseline
    // (every fetch on the critical path) relative to the flat switch.
    let topologies = [
        Topology::Flat,
        Topology::TwoTier { racks: 2, oversubscription: 8.0 },
        Topology::Ring,
        Topology::Star { hub: 0 },
    ];
    for engine in [Engine::Rapid, Engine::DglMetis] {
        let flat = coordinator::run(&tiny_cfg(engine)).unwrap();
        for topo in topologies {
            let mut cfg = tiny_cfg(engine);
            cfg.fabric.topology = topo;
            let r = coordinator::run(&cfg).unwrap();
            assert_eq!(
                r.total_remote_rows(),
                flat.total_remote_rows(),
                "{} on {}: rows must be topology-invariant",
                engine.name(),
                topo.id()
            );
        }
    }
    let mut spine = tiny_cfg(Engine::DglMetis);
    spine.fabric.topology = Topology::TwoTier { racks: 2, oversubscription: 8.0 };
    let flat = coordinator::run(&tiny_cfg(Engine::DglMetis)).unwrap();
    let slow = coordinator::run(&spine).unwrap();
    assert!(
        slow.total_time > flat.total_time,
        "oversubscribed spine {} !> flat {}",
        slow.total_time,
        flat.total_time
    );
}

#[test]
fn full_mode_cluster_runtime_matches_trace_on_every_topology() {
    // The Fig-6 acceptance invariant, in-tree: on each topology the
    // event-driven full mode (concurrent worker actors, shared model) counts
    // exactly the trace-mode communication.
    for topo in [
        Topology::Flat,
        Topology::TwoTier { racks: 2, oversubscription: 4.0 },
        Topology::Ring,
        Topology::Star { hub: 1 },
    ] {
        let mut trace = tiny_cfg(Engine::Rapid);
        trace.batch_size = 64;
        trace.epochs = 2;
        trace.fabric.topology = topo;
        let mut full = trace.clone();
        full.exec_mode = ExecMode::Full;
        let rt = coordinator::run(&trace).unwrap();
        let rf = coordinator::run(&full).unwrap();
        assert_eq!(
            rt.total_remote_rows(),
            rf.total_remote_rows(),
            "topology {}",
            topo.id()
        );
        assert_eq!(rt.sync_remote_rows(), rf.sync_remote_rows(), "topology {}", topo.id());
        assert!((rt.cache_hit_rate() - rf.cache_hit_rate()).abs() < 1e-12);
    }
}

#[test]
fn prefetcher_overlaps_with_slow_consumer() {
    // With a deliberately slow consumer, the prefetcher should have the next
    // batch ready (non-blocking recv succeeds) most of the time — real
    // pipelining, not just the analytic model.
    let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), false);
    let part = Arc::new(metis_like(&ds.graph, 2, 0));
    let kv = Arc::new(KvStore::new(&ds, part.clone(), NetFabric::new(FabricConfig::default())));
    let shard: Vec<u32> = ds
        .train_nodes
        .iter()
        .copied()
        .filter(|&v| part.is_local(0, v))
        .collect();
    let sched = enumerate_epoch(
        &ds.graph,
        &part,
        &shard,
        &[Fanout::Sample(4), Fanout::Sample(4)],
        32,
        1,
        0,
        0,
    );
    let n = sched.batches.len();
    assert!(n >= 8, "need enough batches");
    let cache = Arc::new(Mutex::new(DoubleBufferCache::default()));
    let pf = Prefetcher::spawn(kv, cache, Box::new(sched.batches.into_iter()), 4, 0, false);
    let mut ready_immediately = 0;
    let mut got = 0;
    // warm-up: let it fill the queue
    std::thread::sleep(std::time::Duration::from_millis(50));
    loop {
        match pf.try_recv() {
            Some(_) => {
                ready_immediately += 1;
                got += 1;
            }
            None => {
                // simulate slow consume; if the stream is done, recv returns None
                match pf.recv() {
                    Some(_) => got += 1,
                    None => break,
                }
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let _ = pf.join();
    assert_eq!(got, n);
    assert!(
        ready_immediately * 2 >= n,
        "prefetcher kept up for only {ready_immediately}/{n} batches"
    );
}

#[test]
fn trainer_fallback_recovers_batches_a_dead_prefetcher_dropped() {
    // The paper's race-fallback: if the Prefetcher fails to deliver a batch,
    // the Trainer fetches it through the default path. Simulate a prefetcher
    // that dies halfway (truncated source) and verify the resume-from-disk
    // pattern reconstructs the remaining batches identically.
    let ds = build_dataset(&DatasetConfig::preset(DatasetPreset::Tiny, 1.0), false);
    let part = Arc::new(metis_like(&ds.graph, 2, 0));
    let kv = Arc::new(KvStore::new(&ds, part.clone(), NetFabric::new(FabricConfig::default())));
    let shard: Vec<u32> = ds
        .train_nodes
        .iter()
        .copied()
        .filter(|&v| part.is_local(0, v))
        .collect();
    let sched = enumerate_epoch(
        &ds.graph,
        &part,
        &shard,
        &[Fanout::Sample(4), Fanout::Sample(4)],
        64,
        1,
        0,
        0,
    );
    let n = sched.batches.len();
    assert!(n >= 4);
    let half = n / 2;
    let cache = Arc::new(Mutex::new(DoubleBufferCache::default()));
    // prefetcher only sees the first half (simulated death)
    let pf = Prefetcher::spawn(
        kv.clone(),
        cache.clone(),
        Box::new(sched.batches[..half].to_vec().into_iter()),
        2,
        0,
        false,
    );
    let mut got: Vec<u32> = Vec::new();
    while let Some(b) = pf.recv() {
        got.push(b.meta.batch);
    }
    let _ = pf.join();
    assert_eq!(got.len(), half, "prefetcher delivered only the first half");
    // trainer-side fallback: continue from the full schedule on 'disk'
    let mut stats = rapidgnn::metrics::CommStats::default();
    for meta in sched.batches[got.len()..].iter().cloned() {
        let staged = rapidgnn::prefetch::stage_batch(&kv, &cache, meta, 0, false, &mut stats);
        got.push(staged.meta.batch);
    }
    let expect: Vec<u32> = sched.batches.iter().map(|b| b.batch).collect();
    assert_eq!(got, expect, "every batch trains exactly once, in order");
}

#[test]
fn deterministic_end_to_end_reports() {
    for engine in coordinator::EngineRegistry::global().engines() {
        let a = coordinator::run(&tiny_cfg(engine)).unwrap();
        let b = coordinator::run(&tiny_cfg(engine)).unwrap();
        assert_eq!(a.total_remote_rows(), b.total_remote_rows(), "{}", engine.name());
        assert!((a.total_time - b.total_time).abs() < 1e-12, "{}", engine.name());
        assert_eq!(a.to_json(), b.to_json(), "{}", engine.name());
    }
}

#[test]
fn different_seeds_change_schedule_but_not_scale() {
    let mut a_cfg = tiny_cfg(Engine::Rapid);
    a_cfg.base_seed = 1;
    let mut b_cfg = tiny_cfg(Engine::Rapid);
    b_cfg.base_seed = 2;
    let a = coordinator::run(&a_cfg).unwrap();
    let b = coordinator::run(&b_cfg).unwrap();
    assert_ne!(a.total_remote_rows(), b.total_remote_rows(), "seeds must matter");
    // but magnitudes stay in family (same distribution per Prop 3.1)
    let ratio = a.total_remote_rows() as f64 / b.total_remote_rows() as f64;
    assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
}

#[test]
fn larger_q_never_slows_rapid() {
    let mut times = Vec::new();
    for q in [1u32, 4, 16] {
        let mut cfg = tiny_cfg(Engine::Rapid);
        cfg.prefetch_q = q;
        times.push(coordinator::run(&cfg).unwrap().total_time);
    }
    assert!(times[1] <= times[0] + 1e-9);
    assert!(times[2] <= times[1] + 1e-9);
}

#[test]
fn bigger_cache_reduces_sync_traffic() {
    let mut prev = u64::MAX;
    for n_hot in [1u32, 200, 800] {
        let mut cfg = tiny_cfg(Engine::Rapid);
        cfg.n_hot = n_hot;
        let rows = coordinator::run(&cfg).unwrap().sync_remote_rows();
        assert!(rows <= prev, "n_hot {n_hot}: {rows} > {prev}");
        prev = rows;
    }
}

#[test]
fn run_report_json_artifact_is_parseable() {
    let r = coordinator::run(&tiny_cfg(Engine::Rapid)).unwrap();
    let v = rapidgnn::util::value::Value::from_json(&r.to_json()).unwrap();
    assert_eq!(v.req_str("engine").unwrap(), "RapidGNN");
    assert!(v.req_f64("total_time").unwrap() > 0.0);
}
