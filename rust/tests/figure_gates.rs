//! Tier-1 versions of the figure-bench acceptance gates.
//!
//! The Fig-5b adaptive-vs-static gate and the Fig-9b grad-topk convergence
//! gate originally lived only in `cargo bench` binaries, so a regression
//! could land and sit unnoticed until the next bench sweep. These tests
//! re-run both gates at 0.05× dataset scale (one preset per gate) so they
//! ride in `cargo test` on every push. The bench binaries keep the full
//! paper-scale sweeps; thresholds here are identical.

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, ExecMode, RunConfig};
use rapidgnn::coordinator;

/// Fig-5 setup at test scale: products-sim trace run, 2 workers (the
/// paper's Fig-5 machine count), one batch size.
fn fig5_cfg(engine: Engine, n_hot: u32) -> RunConfig {
    RunConfig {
        dataset: DatasetConfig::preset(DatasetPreset::ProductsSim, 0.05),
        engine,
        num_workers: 2,
        batch_size: 256,
        epochs: 6,
        n_hot,
        ..Default::default()
    }
}

/// Fig-5b gate: the adaptive controller, started at the sweep's
/// second-smallest static size, must climb to within 5 points of the best
/// static cell's hit rate without ever leaving its `[min_hot, max_hot]`
/// envelope; started oversized with a shrink-only policy, capacity must be
/// monotonically released inside the clamps.
#[test]
fn fig5_adaptive_controller_matches_best_static_cell() {
    let sizes = [256u32, 512, 1024, 2048];
    let max_hot = *sizes.last().unwrap();
    let best_static = sizes
        .iter()
        .map(|&n| coordinator::run(&fig5_cfg(Engine::Rapid, n)).unwrap().cache_hit_rate())
        .fold(0.0, f64::max);

    let adaptive = |start: u32, target: f64, tail: f64| {
        let mut cfg = fig5_cfg(Engine::AdaptiveCache, start);
        cfg.epochs = 8; // headroom for the size trajectory to settle
        cfg.engine_params.resize_period = 1;
        cfg.engine_params.min_hot = 64;
        cfg.engine_params.max_hot = max_hot;
        cfg.engine_params.target_hit_rate = target;
        cfg.engine_params.tail_utility = tail;
        cfg.engine_params.hot_growth = 2.0;
        coordinator::run(&cfg).unwrap()
    };

    // Grow cell: undersized start, growth-only controller.
    let grow = adaptive(sizes[1], 1.0, 0.0);
    assert!(
        grow.peak_n_hot() <= max_hot,
        "adaptive exceeded max_hot ({} > {max_hot})",
        grow.peak_n_hot()
    );
    assert!(
        grow.final_epoch_hit_rate() >= best_static - 0.05,
        "adaptive steady-state hit {:.3} below best static {:.3} - 5%",
        grow.final_epoch_hit_rate(),
        best_static
    );

    // Shrink cell: oversized start, shrink-only controller.
    let shrink = adaptive(max_hot, 0.0, 0.02);
    let mut prev = u32::MAX;
    for (e, cp) in shrink.cache_timeline().filter(|(e, _)| e.worker == 0) {
        assert!(cp.n_hot <= prev, "epoch {}: shrink-only run grew", e.epoch);
        assert!(cp.n_hot >= 64 && cp.n_hot <= max_hot, "clamps violated");
        prev = cp.n_hot;
    }
}

/// Fig-9 setup at test scale: full-exec host training, identical model init
/// and seed stream per pair so the gap isolates the optimizer-step change.
fn fig9_cfg(engine: Engine) -> RunConfig {
    let mut ds = DatasetConfig::preset(DatasetPreset::ProductsSim, 0.05);
    ds.train_fraction = 0.5;
    RunConfig {
        dataset: ds,
        engine,
        exec_mode: ExecMode::Full,
        num_workers: 2,
        batch_size: 128,
        fanout: vec![5, 10],
        epochs: 6,
        n_hot: 1_000,
        learning_rate: 0.08,
        ..Default::default()
    }
}

/// Fig-9b gate: error-fed top-k gradient sparsification at the default
/// k = 10% must land its final loss within 2% relative of the dense run,
/// and must surface gradient-compression telemetry in the report.
#[test]
fn fig9_grad_topk_final_loss_stays_within_two_percent_of_dense() {
    let dense = coordinator::run(&fig9_cfg(Engine::Rapid)).unwrap();
    let sparse = coordinator::run(&fig9_cfg(Engine::GradTopk)).unwrap();
    let fd = dense.loss_curve().last().unwrap().1;
    let fs = sparse.loss_curve().last().unwrap().1;
    assert!(fd.is_finite() && fd > 0.0, "dense run produced no usable loss ({fd})");
    let rel = (fs - fd).abs() / fd;
    assert!(
        rel < 0.02,
        "grad-topk final loss {fs:.4} strays {:.2}% from dense {fd:.4} (gate: < 2%)",
        rel * 100.0
    );
    let comp = sparse.compression.as_ref().expect("grad-topk must report gradient telemetry");
    assert!(comp.grad_elems_total > 0);
    assert!(
        comp.grad_elems_sent < comp.grad_elems_total,
        "sparsifier sent every coordinate — top-k never engaged"
    );
    assert!(dense.compression.is_none(), "dense rapid run must not report compression");
}
