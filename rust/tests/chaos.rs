//! Chaos-conformance suite: elastic fault-tolerance under deterministic,
//! seeded failure schedules.
//!
//! The failure model's contract (see `sim/README.md`): failures land on
//! epoch boundaries and heal entirely within them through *priced* recovery
//! work, so the training timeline — schedules, caches, communication
//! counters, SGD trajectory — replays the failure-free run exactly. These
//! tests drive randomly generated failure plans (via `proptest_lite`, so
//! every case reproduces from its seed) across engines × topologies ×
//! contention modes and pin:
//!
//! 1. **Timeline invariance** — any epoch-boundary failure schedule leaves
//!    per-(worker, epoch) communication counters (and, in full mode, the
//!    loss/accuracy curves) identical to the failure-free run.
//! 2. **Kill–restore exactness** — checkpoint → kill → resume produces a
//!    run report byte-identical to the uninterrupted run, across engines
//!    with real checkpoint state (caches, controllers, residuals, codec
//!    tallies) and both exec modes.
//! 3. **Thread-count independence** — chaos runs and resumed runs render
//!    byte-identical reports at `RAPIDGNN_THREADS ∈ {1, 2, 8}`.

use rapidgnn::config::{
    DatasetConfig, DatasetPreset, Engine, FailureEvent, FailurePlan, RunConfig, Topology,
};
use rapidgnn::coordinator::{self, resume_run};
use rapidgnn::metrics::EpochReport;
use rapidgnn::sampler::seed::Rng;
use rapidgnn::util::proptest_lite::{forall, gen};
use rapidgnn::util::tempdir::TempDir;
use std::sync::{Mutex, MutexGuard, OnceLock};

const WORKERS: u32 = 3;
const EPOCHS: u32 = 4;

/// One test mutates the process-global `RAPIDGNN_THREADS`; serialize all
/// run-rendering tests against it (same idiom as the golden-trace suite).
fn env_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn base_cfg(engine: Engine, topology: Topology, contention: bool) -> RunConfig {
    let mut c = RunConfig::default();
    c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
    c.engine = engine;
    c.num_workers = WORKERS;
    c.epochs = EPOCHS;
    c.n_hot = 300;
    c.fabric.topology = topology;
    c.fabric.contention = contention;
    c
}

/// A random failure schedule: 1–4 events on interior boundaries, all five
/// event kinds, self-links excluded. Deterministic in the driving `Rng`.
fn random_plan(rng: &mut Rng) -> FailurePlan {
    let n = gen::usize_in(rng, 1, 4);
    let mut events = Vec::with_capacity(n);
    for _ in 0..n {
        let at_epoch = gen::usize_in(rng, 1, (EPOCHS - 1) as usize) as u32;
        let ev = match rng.below(5) {
            0 => FailureEvent::WorkerLeave { worker: rng.below(WORKERS), at_epoch },
            1 => FailureEvent::WorkerJoin { worker: rng.below(WORKERS), at_epoch },
            kind @ (2 | 3) => {
                let a = rng.below(WORKERS);
                let b = (a + 1 + rng.below(WORKERS - 1)) % WORKERS;
                if kind == 2 {
                    FailureEvent::LinkDown { a, b, at_epoch }
                } else {
                    FailureEvent::LinkUp { a, b, at_epoch }
                }
            }
            _ => FailureEvent::CrashRestart { at_epoch },
        };
        events.push(ev);
    }
    FailurePlan { events }
}

/// Per-(worker, epoch) reports in a path-independent order.
fn sorted(mut epochs: Vec<EpochReport>) -> Vec<EpochReport> {
    epochs.sort_by_key(|e| (e.worker, e.epoch));
    epochs
}

/// Compare the schedule-derived counters of two runs. Virtual times are
/// deliberately excluded: the failure-free reference may run on the
/// trace-mode per-worker path while chaos runs use the cluster runtime,
/// and only communication counts are pinned across those paths (the same
/// contract the Fig-6 conformance test uses).
fn assert_same_timeline(tag: &str, a: &[EpochReport], b: &[EpochReport]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{tag}: {} vs {} epoch reports", a.len(), b.len()));
    }
    for (x, y) in a.iter().zip(b) {
        let ctx = format!("{tag} worker {} epoch {}", x.worker, x.epoch);
        if (x.worker, x.epoch) != (y.worker, y.epoch) {
            return Err(format!("{ctx}: misaligned against ({}, {})", y.worker, y.epoch));
        }
        if x.steps != y.steps {
            return Err(format!("{ctx}: steps {} != {}", x.steps, y.steps));
        }
        if x.comm.remote_rows != y.comm.remote_rows {
            return Err(format!(
                "{ctx}: remote_rows {} != {}",
                x.comm.remote_rows, y.comm.remote_rows
            ));
        }
        if x.comm.vector_rows != y.comm.vector_rows {
            return Err(format!(
                "{ctx}: vector_rows {} != {}",
                x.comm.vector_rows, y.comm.vector_rows
            ));
        }
        if x.comm.bytes != y.comm.bytes {
            return Err(format!("{ctx}: bytes {} != {}", x.comm.bytes, y.comm.bytes));
        }
        if x.cache.lookups != y.cache.lookups || x.cache.hits != y.cache.hits {
            return Err(format!(
                "{ctx}: cache {}/{} != {}/{}",
                x.cache.hits, x.cache.lookups, y.cache.hits, y.cache.lookups
            ));
        }
    }
    Ok(())
}

#[test]
fn timeline_is_failure_invariant_across_engines_and_topologies() {
    let _guard = env_lock();
    let engines = [Engine::Rapid, Engine::DglMetis, Engine::AdaptiveCache];
    let topologies =
        [Topology::Ring, Topology::TwoTier { racks: 2, oversubscription: 4.0 }];
    for (ei, &engine) in engines.iter().enumerate() {
        for (ti, &topology) in topologies.iter().enumerate() {
            let clean = coordinator::run(&base_cfg(engine, topology, false)).unwrap();
            assert!(clean.recovery.is_none(), "failure-free run must omit recovery");
            let reference = sorted(clean.epochs);
            // 8 seeded schedules per cell; failures deterministic per seed.
            let seed = 0xC4A0_5000 + (ei * 10 + ti) as u64;
            forall(seed, 8, random_plan, |plan| {
                let mut cfg = base_cfg(engine, topology, false);
                cfg.failures = plan.encode();
                cfg.checkpoint_every = 2;
                let report = coordinator::run(&cfg).map_err(|e| e.to_string())?;
                let rec = report
                    .recovery
                    .as_ref()
                    .ok_or("chaos run must report recovery telemetry")?;
                if rec.events != plan.events.len() as u32 {
                    return Err(format!(
                        "{} events applied for a {}-event plan",
                        rec.events,
                        plan.events.len()
                    ));
                }
                assert_same_timeline(engine.id(), &sorted(report.epochs), &reference)
            });
        }
    }
}

#[test]
fn full_mode_model_trajectory_is_failure_invariant() {
    let _guard = env_lock();
    for engine in [Engine::Rapid, Engine::GradTopk] {
        let mut clean_cfg = base_cfg(engine, Topology::Flat, false);
        clean_cfg.exec_mode = rapidgnn::config::ExecMode::Full;
        clean_cfg.batch_size = 64;
        clean_cfg.epochs = 3;
        let clean = coordinator::run(&clean_cfg).unwrap();

        let mut cfg = clean_cfg.clone();
        cfg.failures = "linkdown:0-1@1,leave:1@1,crash@2,linkup:0-1@2,join:2@2".into();
        cfg.checkpoint_every = 1;
        let chaos = coordinator::run(&cfg).unwrap();

        // Full mode always runs on the cluster runtime, so the SGD
        // trajectory must be bit-identical — not merely close.
        assert_eq!(clean.loss_curve(), chaos.loss_curve(), "{}", engine.id());
        assert_eq!(clean.accuracy_curve(), chaos.accuracy_curve(), "{}", engine.id());
        assert_eq!(clean.total_remote_rows(), chaos.total_remote_rows(), "{}", engine.id());
        let rec = chaos.recovery.unwrap();
        assert_eq!(rec.events, 5);
        assert!(rec.moved_rows > 0);
        assert!(rec.rerouted_bytes > 0, "boundary-1 move crosses the downed 0-1 link");
        assert!(rec.lost_work_time > 0.0);
    }
}

/// Cells for the kill–restore matrix: every engine family with real
/// checkpoint state, both exec modes, a contended cell included.
fn restore_cells() -> Vec<RunConfig> {
    let trace = |e: Engine, t: Topology, cont: bool| base_cfg(e, t, cont);
    let full = |e: Engine| {
        let mut c = base_cfg(e, Topology::Flat, false);
        c.exec_mode = rapidgnn::config::ExecMode::Full;
        c.batch_size = 64;
        c.epochs = 3;
        c
    };
    vec![
        trace(Engine::Rapid, Topology::Ring, false),
        trace(Engine::FastSample, Topology::Flat, false),
        trace(
            Engine::AdaptiveCache,
            Topology::TwoTier { racks: 2, oversubscription: 4.0 },
            true,
        ),
        full(Engine::Rapid),
        full(Engine::GradTopk),
        full(Engine::QuantPull),
    ]
}

#[test]
fn checkpoint_kill_restore_is_bit_exact() {
    let _guard = env_lock();
    for mut cfg in restore_cells() {
        let dir = TempDir::new("chaos-ckpt").unwrap();
        cfg.checkpoint_every = 1;
        cfg.checkpoint_dir = dir.path().to_str().unwrap().to_string();
        cfg.failures = "leave:1@1,crash@2".into();
        let tag = format!("{} ({:?})", cfg.engine.id(), cfg.exec_mode);
        let uninterrupted = coordinator::run(&cfg).unwrap().to_json();
        // Kill after each checkpoint boundary in turn; every resume must
        // reproduce the uninterrupted report byte-for-byte (epoch reports,
        // recovery block, link telemetry, compression tally, energy).
        for boundary in 1..cfg.epochs {
            let resumed = resume_run(&dir.path().join(format!("checkpoint-{boundary}.json")))
                .unwrap()
                .to_json();
            assert_eq!(uninterrupted, resumed, "{tag}: resume from boundary {boundary}");
        }
    }
}

#[test]
fn recovery_traffic_surfaces_in_contended_link_telemetry() {
    let _guard = env_lock();
    let cfg = base_cfg(Engine::Rapid, Topology::TwoTier { racks: 2, oversubscription: 4.0 }, true);
    let clean = coordinator::run(&cfg).unwrap();
    let mut chaos_cfg = cfg.clone();
    chaos_cfg.failures = "leave:1@2".into();
    let chaos = coordinator::run(&chaos_cfg).unwrap();
    // Same training timeline (both on the contended cluster path)...
    assert_same_timeline("contended", &sorted(chaos.epochs.clone()), &sorted(clean.epochs.clone()))
        .unwrap();
    // ...but the shard + cache move shows up as extra served bytes on links.
    let served = |r: &rapidgnn::metrics::RunReport| -> f64 {
        r.links.iter().map(|l| l.served_bytes).sum()
    };
    let moved = chaos.recovery.as_ref().unwrap().moved_bytes;
    assert!(moved > 0);
    assert!(
        served(&chaos) > served(&clean),
        "recovery flows must appear in link telemetry: {} !> {}",
        served(&chaos),
        served(&clean)
    );
}

#[test]
fn chaos_and_resume_are_byte_stable_across_thread_counts() {
    let _guard = env_lock();
    let prev = std::env::var("RAPIDGNN_THREADS").ok();
    let dir = TempDir::new("chaos-threads").unwrap();
    let render = || {
        let mut cfg = base_cfg(
            Engine::AdaptiveCache,
            Topology::TwoTier { racks: 2, oversubscription: 4.0 },
            true,
        );
        cfg.failures = "linkdown:0-1@1,leave:1@1,linkup:0-1@2,crash@3,join:2@3".into();
        cfg.checkpoint_every = 2;
        cfg.checkpoint_dir = dir.path().to_str().unwrap().to_string();
        coordinator::run(&cfg).unwrap().to_json()
    };
    std::env::set_var("RAPIDGNN_THREADS", "1");
    let serial = render();
    let resumed_serial = resume_run(&dir.path().join("checkpoint-2.json")).unwrap().to_json();
    assert_eq!(serial, resumed_serial, "threads=1 resume");
    for threads in ["2", "8"] {
        std::env::set_var("RAPIDGNN_THREADS", threads);
        assert_eq!(serial, render(), "threads={threads} changed the chaos report");
        let resumed = resume_run(&dir.path().join("checkpoint-2.json")).unwrap().to_json();
        assert_eq!(serial, resumed, "threads={threads} changed the resumed report");
    }
    match prev {
        Some(v) => std::env::set_var("RAPIDGNN_THREADS", v),
        None => std::env::remove_var("RAPIDGNN_THREADS"),
    }
}

#[test]
fn failure_plan_spec_round_trips_through_the_generator() {
    // The seeded generator's plans survive encode → parse → encode (the
    // same path `--failures` takes through RunConfig serialization).
    forall(0xC4A0_5FFF, 32, random_plan, |plan| {
        let spec = plan.encode();
        let back = FailurePlan::parse(&spec).map_err(|e| e.to_string())?;
        if back != *plan {
            return Err(format!("parse({spec}) != original"));
        }
        if back.encode() != spec {
            return Err(format!("re-encode of '{spec}' drifted to '{}'", back.encode()));
        }
        Ok(())
    });
}
