//! Tier-1 contract suite for the virtual-time trace journal.
//!
//! Pins the two load-bearing guarantees from the observability design:
//!
//! 1. **Strictly observational** — attaching a `TraceSink` must not perturb a
//!    single reported quantity. The traced run's `RunReport` JSON is compared
//!    byte-for-byte against a sink-free run of the same config.
//! 2. **Thread-count invariant** — the exported JSONL is byte-identical
//!    across `RAPIDGNN_THREADS` ∈ {1, 2, 8}, because records are keyed by
//!    virtual time `(epoch, t, worker, seq)` and never by wall-clock or
//!    scheduling order.
//!
//! Plus coverage that every emission site actually journals: epoch summaries,
//! cluster stage transitions, contention flow enqueue/drain, adaptive-cache
//! resizes, and recovery boundary events.

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
use rapidgnn::coordinator;
use rapidgnn::trace::{parse_jsonl, TraceHandle};
use std::collections::BTreeSet;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// One test mutates the process-global `RAPIDGNN_THREADS`; serialize every
/// trace-rendering test so a run never races the env mutation (cargo's
/// default harness runs tests in parallel threads).
fn env_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Same shape as the golden-trace config: small enough to run in tests,
/// big enough that every pipeline stage does real work.
fn base_cfg(engine: Engine) -> RunConfig {
    let mut c = RunConfig::default();
    c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
    c.engine = engine;
    c.epochs = 2;
    c.n_hot = 300;
    c
}

/// Run `cfg` with a fresh journal attached; returns (report JSON, journal).
fn run_traced(cfg: &RunConfig) -> (String, TraceHandle) {
    let trace = TraceHandle::new();
    let report = coordinator::RunBuilder::new(cfg.clone())
        .with_trace(trace.clone())
        .run()
        .expect("traced run");
    (report.to_json(), trace)
}

fn kinds(trace: &TraceHandle) -> BTreeSet<String> {
    trace.records().iter().map(|r| r.kind.clone()).collect()
}

#[test]
fn tracing_is_strictly_observational() {
    let _guard = env_lock();
    let cfg = base_cfg(Engine::Rapid);
    let plain = coordinator::run(&cfg).expect("plain run").to_json();
    let (traced, trace) = run_traced(&cfg);
    assert_eq!(plain, traced, "attaching a trace sink changed the RunReport");
    assert!(!trace.is_empty(), "traced run journaled nothing");
    assert!(kinds(&trace).contains("epoch"), "missing epoch summaries: {:?}", kinds(&trace));
}

#[test]
fn trace_jsonl_is_byte_identical_across_thread_counts() {
    let _guard = env_lock();
    let cfg = base_cfg(Engine::Rapid);
    let prev = std::env::var("RAPIDGNN_THREADS").ok();
    std::env::set_var("RAPIDGNN_THREADS", "1");
    let serial = run_traced(&cfg).1.to_jsonl();
    for threads in ["2", "8"] {
        std::env::set_var("RAPIDGNN_THREADS", threads);
        let parallel = run_traced(&cfg).1.to_jsonl();
        assert_eq!(serial, parallel, "threads={threads} changed the trace JSONL");
    }
    match prev {
        Some(v) => std::env::set_var("RAPIDGNN_THREADS", v),
        None => std::env::remove_var("RAPIDGNN_THREADS"),
    }
    assert!(!serial.is_empty());
}

#[test]
fn contention_run_journals_stage_and_flow_events() {
    let _guard = env_lock();
    let mut cfg = base_cfg(Engine::Rapid);
    cfg.fabric.contention = true;
    let (_, trace) = run_traced(&cfg);
    let got = kinds(&trace);
    for kind in ["epoch", "stage-done", "consume-done", "flow-enqueue", "flow-drain"] {
        assert!(got.contains(kind), "missing `{kind}` records; journaled kinds: {got:?}");
    }
}

#[test]
fn adaptive_cache_resizes_are_journaled() {
    let _guard = env_lock();
    // Deliberately undersized cache with aggressive growth targets: the same
    // config the adaptive-cache unit tests use to guarantee the controller
    // fires at least one grow decision.
    let mut cfg = base_cfg(Engine::AdaptiveCache);
    cfg.n_hot = 8;
    cfg.epochs = 6;
    cfg.engine_params.min_hot = 8;
    cfg.engine_params.max_hot = 800;
    cfg.engine_params.target_hit_rate = 0.99;
    cfg.engine_params.tail_utility = 0.0;
    let (_, trace) = run_traced(&cfg);
    let resizes: Vec<_> =
        trace.records().into_iter().filter(|r| r.kind == "cache-resize").collect();
    assert!(!resizes.is_empty(), "undersized adaptive run journaled no cache-resize");
    let first = &resizes[0];
    let from = first.fields.req_u32("from").expect("from field");
    let to = first.fields.req_u32("to").expect("to field");
    assert!(to > from, "first resize of an undersized cache must grow ({from} -> {to})");
}

#[test]
fn recovery_events_are_journaled() {
    let _guard = env_lock();
    let mut cfg = base_cfg(Engine::Rapid);
    cfg.failures = "leave:1@1".into();
    let (_, trace) = run_traced(&cfg);
    let recs: Vec<_> = trace.records().into_iter().filter(|r| r.kind == "recovery").collect();
    assert_eq!(recs.len(), 1, "one failure event, one recovery record");
    assert_eq!(recs[0].worker, 1);
    assert_eq!(recs[0].epoch, 1);
    assert_eq!(recs[0].fields.req_str("event").expect("event field"), "worker-leave");
}

#[test]
fn records_are_globally_sorted_and_round_trip_through_jsonl() {
    let _guard = env_lock();
    let mut cfg = base_cfg(Engine::Rapid);
    cfg.fabric.contention = true;
    let (_, trace) = run_traced(&cfg);
    let records = trace.records();
    for pair in records.windows(2) {
        let a = (pair[0].epoch, pair[0].t, pair[0].worker, pair[0].seq);
        let b = (pair[1].epoch, pair[1].t, pair[1].worker, pair[1].seq);
        let ordered = a.0 < b.0
            || (a.0 == b.0 && a.1 < b.1)
            || (a.0 == b.0 && a.1 == b.1 && (a.2, a.3) <= (b.2, b.3));
        assert!(ordered, "records out of (epoch, t, worker, seq) order: {a:?} then {b:?}");
    }
    let parsed = parse_jsonl(&trace.to_jsonl()).expect("parse our own JSONL");
    assert_eq!(parsed, records, "JSONL round-trip must be lossless");
}
