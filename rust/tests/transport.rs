//! Transport-backend conformance suite: the `--exec wallclock` contract.
//!
//! Wallclock mode runs trace scheduling on the real shared-memory transport
//! (`net::transport::ShmRings`): worker threads actually move serialized
//! feature bytes for every KvStore pull. The contract is that the *modeled*
//! report is untouched by the backend swap — `remote_rows`,
//! `sync_remote_rows`, bytes, and simulated times must equal the simulated
//! trace **exactly**, for every registered engine, at any worker-thread
//! count. The only addition is the `calibration` section (measured
//! wall-clock vs modeled virtual time), which never steers a run.

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, ExecMode, RunConfig};
use rapidgnn::coordinator;
use rapidgnn::metrics::RunReport;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Every registered engine: conformance is per-engine, not per-family.
const ENGINES: [Engine; 9] = [
    Engine::Rapid,
    Engine::DglMetis,
    Engine::DglRandom,
    Engine::DistGcn,
    Engine::FastSample,
    Engine::GreenWindow,
    Engine::AdaptiveCache,
    Engine::QuantPull,
    Engine::GradTopk,
];

/// One test mutates the process-global `RAPIDGNN_THREADS`; serialize all
/// report-rendering tests against it (same pattern as `golden_trace.rs`).
fn env_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn cfg(engine: Engine, exec: ExecMode) -> RunConfig {
    let mut c = RunConfig::default();
    c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
    c.engine = engine;
    c.epochs = 2;
    c.n_hot = 300;
    c.exec_mode = exec;
    c
}

/// Assert the wallclock run's modeled quantities equal the trace run's,
/// epoch by epoch, counter by counter.
fn assert_conformant(engine: Engine, trace: &RunReport, wall: &RunReport) {
    let id = engine.id();
    assert_eq!(
        trace.epochs.len(),
        wall.epochs.len(),
        "{id}: epoch report cardinality"
    );
    for (t, w) in trace.epochs.iter().zip(&wall.epochs) {
        assert_eq!(t.comm, w.comm, "{id} epoch {} worker {}: comm counters", t.epoch, t.worker);
    }
    assert_eq!(trace.total_remote_rows(), wall.total_remote_rows(), "{id}: remote_rows");
    assert_eq!(trace.sync_remote_rows(), wall.sync_remote_rows(), "{id}: sync_remote_rows");
}

#[test]
fn wallclock_matches_trace_for_every_engine() {
    let _guard = env_lock();
    for engine in ENGINES {
        let trace = coordinator::run(&cfg(engine, ExecMode::Trace)).unwrap();
        let wall = coordinator::run(&cfg(engine, ExecMode::Wallclock)).unwrap();
        assert_conformant(engine, &trace, &wall);
        assert!(trace.calibration.is_none(), "{}: trace must not calibrate", engine.id());
        assert!(wall.calibration.is_some(), "{}: wallclock must calibrate", engine.id());
    }
}

#[test]
fn conformance_holds_across_thread_counts() {
    // The shard servers and the worker fan-out both scale with
    // `RAPIDGNN_THREADS`; no thread count may leak into a modeled quantity.
    let _guard = env_lock();
    let prev = std::env::var("RAPIDGNN_THREADS").ok();
    for engine in ENGINES {
        std::env::set_var("RAPIDGNN_THREADS", "1");
        let trace = coordinator::run(&cfg(engine, ExecMode::Trace)).unwrap();
        for threads in ["1", "2", "8"] {
            std::env::set_var("RAPIDGNN_THREADS", threads);
            let wall = coordinator::run(&cfg(engine, ExecMode::Wallclock)).unwrap();
            assert_conformant(engine, &trace, &wall);
        }
    }
    match prev {
        Some(v) => std::env::set_var("RAPIDGNN_THREADS", v),
        None => std::env::remove_var("RAPIDGNN_THREADS"),
    }
}

#[test]
fn wallclock_report_minus_calibration_is_byte_identical_to_trace() {
    // The strongest form of the conformance gate: `RunReport::to_json`
    // serializes every modeled field, so after stripping the calibration
    // section the two documents must not differ in a single byte.
    let _guard = env_lock();
    let trace = coordinator::run(&cfg(Engine::Rapid, ExecMode::Trace)).unwrap();
    let mut wall = coordinator::run(&cfg(Engine::Rapid, ExecMode::Wallclock)).unwrap();
    assert!(wall.to_json().contains("\"calibration\""));
    wall.calibration = None;
    assert_eq!(trace.to_json(), wall.to_json(), "backend swap changed a modeled byte");
}

#[test]
fn calibration_report_is_well_formed() {
    let _guard = env_lock();
    let report = coordinator::run(&cfg(Engine::Rapid, ExecMode::Wallclock)).unwrap();
    let cal = report.calibration.as_ref().expect("wallclock attaches calibration");
    assert_eq!(cal.backend, "shm-rings");
    assert!(cal.run_wall_sec > 0.0, "the stopwatch must have advanced");
    assert!(!cal.epochs.is_empty() && !cal.links.is_empty());

    // Every byte the model charges to a link corresponds to payload the
    // shard servers actually shipped: modeled bytes are payload plus the
    // 64-byte per-RPC envelope, measured bytes are payload alone, and the
    // default fabric has no loss, so the identity is exact per link.
    for l in &cal.links {
        assert_eq!(
            l.modeled_bytes,
            l.measured_bytes + 64 * l.rpcs,
            "link {}: modeled = measured payload + envelopes",
            l.link
        );
        assert!(l.measured_wall_sec >= 0.0);
    }
    let epoch_bytes: u64 = cal.epochs.iter().map(|e| e.measured_bytes).sum();
    let link_bytes: u64 = cal.links.iter().map(|l| l.measured_bytes).sum();
    assert_eq!(epoch_bytes, link_bytes, "per-epoch and per-link tallies must agree");
    assert!(epoch_bytes > 0, "a Tiny run moves real feature bytes");

    // Calibration is additive: the modeled virtual times it reports are the
    // same net_time sums the epoch reports carry.
    let modeled: f64 = cal.epochs.iter().map(|e| e.modeled_net_sec).sum();
    let from_epochs: f64 = report.epochs.iter().map(|e| e.comm.net_time).sum();
    assert!((modeled - from_epochs).abs() < 1e-12);
}

#[test]
fn wallclock_parses_and_round_trips_through_cli_id() {
    let _guard = env_lock();
    assert_eq!("wallclock".parse::<ExecMode>().unwrap(), ExecMode::Wallclock);
    assert_eq!(ExecMode::Wallclock.id(), "wallclock");
}
