//! Golden-trace conformance suite: the end-to-end determinism contract.
//!
//! A `Tiny`-preset trace run is serialized to JSON and compared byte-for-byte
//! against (a) a second run in the same process, (b) runs at different
//! `RAPIDGNN_THREADS` worker counts, and (c) a checked-in fixture. Any change
//! to sampling, ranking, caching, fabric charging, or the event-driven
//! cluster runtime that perturbs a single counter or simulated nanosecond
//! fails loudly here.
//!
//! Blessing: if the fixture file does not exist yet it is written and the
//! test passes (first run in a fresh checkout / CI runner bootstraps it).
//! After an *intentional* behaviour change, refresh it with
//! `UPDATE_GOLDEN=1 cargo test -p rapidgnn --test golden_trace`.

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
use rapidgnn::coordinator;
use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// All three tests render traces and one of them mutates the process-global
/// `RAPIDGNN_THREADS`; serialize them so a renders never races the env
/// mutation (cargo's default harness runs tests in parallel threads).
fn env_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn golden_cfg(engine: Engine) -> RunConfig {
    let mut c = RunConfig::default();
    c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
    c.engine = engine;
    c.epochs = 2;
    c.n_hot = 300;
    c
}

/// The canonical serialized trace: both headline engines in one document
/// (remote rows, cache hit rates, per-epoch times — everything `to_json`
/// emits, which is every field of every `EpochReport`).
fn render_trace() -> String {
    let rapid = coordinator::run(&golden_cfg(Engine::Rapid)).unwrap();
    let metis = coordinator::run(&golden_cfg(Engine::DglMetis)).unwrap();
    format!(
        "{{\n\"rapid\": {},\n\"dgl-metis\": {}\n}}\n",
        rapid.to_json(),
        metis.to_json()
    )
}

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden_tiny_trace.json")
}

#[test]
fn golden_trace_is_byte_stable_across_runs() {
    let _guard = env_lock();
    assert_eq!(render_trace(), render_trace(), "same-process runs must be byte-identical");
}

#[test]
fn golden_trace_is_byte_stable_across_thread_counts() {
    // The parallel schedule precompute, sharded frequency tally, and worker
    // threads must not leak thread count into any reported quantity.
    let _guard = env_lock();
    let prev = std::env::var("RAPIDGNN_THREADS").ok();
    std::env::set_var("RAPIDGNN_THREADS", "1");
    let serial = render_trace();
    for threads in ["2", "8"] {
        std::env::set_var("RAPIDGNN_THREADS", threads);
        let parallel = render_trace();
        assert_eq!(serial, parallel, "threads={threads} changed the report");
    }
    match prev {
        Some(v) => std::env::set_var("RAPIDGNN_THREADS", v),
        None => std::env::remove_var("RAPIDGNN_THREADS"),
    }
}

#[test]
fn golden_trace_matches_checked_in_fixture() {
    let _guard = env_lock();
    let path = fixture_path();
    let rendered = render_trace();
    let bless = std::env::var("UPDATE_GOLDEN").is_ok() || !path.exists();
    if bless {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &rendered).unwrap();
        eprintln!("blessed golden fixture at {}", path.display());
        return;
    }
    let fixture = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        rendered, fixture,
        "trace diverged from {} — if the change is intentional, \
         re-bless with UPDATE_GOLDEN=1",
        path.display()
    );
}
