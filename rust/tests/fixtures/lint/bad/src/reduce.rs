//! Fixture: seeded `unordered-float-reduce` violations.

pub fn total_loss(shards: Vec<Vec<f64>>) -> f64 {
    par_map_threads(shards, 4, |s| s.iter().sum::<f64>()).iter().sum()
}

use rayon::prelude::*;
