//! Fixture: seeded `priced-recovery` violation — recovery must never call a
//! mutating `charge_*` fabric method. (Not compiled; scanned by tests/lint.rs.)

pub fn recover_shard(fabric: &mut Fabric) {
    // The doc-comment spelling of charge_rpc above must NOT fire; this call must:
    fabric.charge_rpc(0, 1, 4096);
    fabric.charge_fanout(0, &[1, 2], 4096);
}
