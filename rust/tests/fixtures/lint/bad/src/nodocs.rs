// Fixture: seeded `module-docs` violation — a plain comment is not `//!` docs.

pub fn undocumented() {}
