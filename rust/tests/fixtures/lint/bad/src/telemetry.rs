//! Fixture: seeded `unordered-collections` violation.

use std::collections::HashMap;

pub fn tally() -> HashMap<u32, u64> {
    HashMap::new()
}
