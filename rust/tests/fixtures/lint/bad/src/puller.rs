//! Fixture: seeded `charge-ladder` violations — the deprecated pre-`ChargeSpec`
//! wrappers (charge_rpc_payload_at and friends) are only legal inside their
//! shim homes. (Not compiled; scanned by tests/lint.rs.)

pub fn fetch(fabric: &Fabric, kv: &KvStore) {
    // The doc-comment spelling above must NOT fire; these two calls must:
    fabric.charge_rpc_payload_at(0, 1, 100, 40_000, 3);
    kv.sync_pull_at(0, &[1, 2, 3], 3, None, &mut Default::default());
}
