//! Fixture: seeded `thread-spawn` violation.

pub fn fan_out() {
    let h = std::thread::spawn(|| 42);
    let b = std::thread::Builder::new().name("w".into());
    let _ = (h, b);
}
