//! Fixture: seeded `wall-clock` violation.

pub fn stamp() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn epoch_ns() -> u128 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos()
}
