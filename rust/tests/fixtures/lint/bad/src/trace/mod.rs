//! Seeded trace-sink violation: observability code printing to stdout.

pub fn flush_to_stdout(line: &str) {
    println!("{line}");
}
