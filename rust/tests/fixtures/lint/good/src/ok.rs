//! Fixture: every forbidden pattern below carries a well-formed allow
//! marker, so this tree must scan clean (exercised by tests/lint.rs).
// lint:allow-file(wall-clock): fixture demonstrating the file-scope marker form

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}

// lint:allow(unordered-collections): fixture demonstrating the line-scope marker form
pub fn tally() -> std::collections::HashMap<u32, u64> {
    // the marker covers its own line and the next; re-annotate further uses
    std::collections::BTreeMap::new().into_iter().collect()
}

pub fn spawn_worker() {
    // lint:allow(thread-spawn): fixture demonstrating a same-line-plus-next marker
    let h = std::thread::spawn(|| 7);
    let _ = h;
}
