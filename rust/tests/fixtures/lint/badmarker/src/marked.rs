//! Fixture: markers that are malformed (no justification) or name an
//! unknown rule must themselves be violations, never silent no-ops.

// lint:allow(thread-spawn)
pub fn unjustified() {}

// lint:allow(no-such-rule): the rule name is a typo
pub fn unknown_rule() {}
