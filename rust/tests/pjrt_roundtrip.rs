//! Integration: the AOT-compiled JAX/Pallas artifact, executed from rust via
//! PJRT, must agree with the pure-rust host trainer — same init, same
//! batches, matching losses/params over several SGD steps. This closes the
//! three-layer loop: Pallas kernel → JAX model → HLO text → rust runtime.
//!
//! Requires `make artifacts` (skips with a message when absent so `cargo
//! test` stays green on a fresh checkout).

use rapidgnn::config::{DatasetConfig, DatasetPreset, RunConfig};
use rapidgnn::coordinator::RunContext;
use rapidgnn::graph::build_dataset;
use rapidgnn::runtime::{artifacts_dir, find_artifact, PjrtTrainer};
use rapidgnn::sampler::{sample_blocks, Fanout};
use rapidgnn::trainer::{batch_labels, Mat, SageModel, TrainStep};

fn tiny_ctx() -> RunContext {
    let mut c = RunConfig::default();
    c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
    RunContext::build(&c).unwrap()
}

fn load_trainer(ctx: &RunContext) -> Option<PjrtTrainer> {
    let meta = match find_artifact(&artifacts_dir(), ctx) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP pjrt_roundtrip: {e}");
            return None;
        }
    };
    Some(PjrtTrainer::load(meta, ctx.cfg.base_seed).expect("compile artifact"))
}

fn make_batch(
    ctx: &RunContext,
    seed: u64,
    n_seeds: usize,
) -> (rapidgnn::sampler::SampledBatch, Mat, Vec<u16>) {
    let ds = build_dataset(&ctx.cfg.dataset, true);
    let seeds: Vec<u32> = ds.train_nodes.iter().take(n_seeds).copied().collect();
    let fanouts: Vec<Fanout> = ctx.cfg.fanout.iter().map(|&f| Fanout::Sample(f)).collect();
    let batch = sample_blocks(&ds.graph, &seeds, &fanouts, seed);
    let d = ds.config.feature_dim as usize;
    let mut x0 = Mat::zeros(batch.node_layers[0].len(), d);
    for (i, &v) in batch.node_layers[0].iter().enumerate() {
        x0.row_mut(i).copy_from_slice(ds.feature_row(v));
    }
    let labels = batch_labels(&ds, &batch);
    (batch, x0, labels)
}

#[test]
fn pjrt_matches_host_over_training() {
    let ctx = tiny_ctx();
    let Some(mut pjrt) = load_trainer(&ctx) else { return };
    let mut host = SageModel::new(
        ctx.cfg.dataset.feature_dim as usize,
        ctx.cfg.hidden_dim as usize,
        ctx.cfg.dataset.num_classes as usize,
        2,
        ctx.cfg.base_seed,
    );

    for step in 0..5u64 {
        let (batch, x0, labels) = make_batch(&ctx, 100 + step, 64);
        let h = host.step(&x0, &batch, &labels, 0.05);
        let p = pjrt.step(&x0, &batch, &labels, 0.05);
        assert!(
            (h.loss - p.loss).abs() < 1e-3 * h.loss.abs().max(1.0),
            "step {step}: host loss {} vs pjrt {}",
            h.loss,
            p.loss
        );
        assert_eq!(h.correct, p.correct, "step {step} correct count");
        assert_eq!(h.total, p.total);
    }

    // Parameters stay in lockstep after several updates.
    let pjrt_params = pjrt.params_flat().unwrap();
    let host_flat: Vec<Vec<f32>> = host
        .layers
        .iter()
        .flat_map(|l| {
            vec![
                l.w_self.data.clone(),
                l.w_nbr.data.clone(),
                l.bias.clone(),
            ]
        })
        .collect();
    assert_eq!(pjrt_params.len(), host_flat.len());
    for (i, (p, h)) in pjrt_params.iter().zip(&host_flat).enumerate() {
        assert_eq!(p.len(), h.len(), "param {i} shape");
        let max_diff = p
            .iter()
            .zip(h)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_diff < 2e-4, "param {i} diverged by {max_diff}");
    }
}

#[test]
fn pjrt_eval_does_not_mutate_params() {
    let ctx = tiny_ctx();
    let Some(mut pjrt) = load_trainer(&ctx) else { return };
    let before = pjrt.params_flat().unwrap();
    let (batch, x0, labels) = make_batch(&ctx, 7, 32);
    let out = pjrt.eval(&x0, &batch, &labels);
    assert!(out.loss.is_finite());
    let after = pjrt.params_flat().unwrap();
    assert_eq!(before, after, "eval must not update parameters");
}

#[test]
fn pjrt_loss_decreases_with_training() {
    let ctx = tiny_ctx();
    let Some(mut pjrt) = load_trainer(&ctx) else { return };
    let (batch, x0, labels) = make_batch(&ctx, 42, 64);
    let first = pjrt.step(&x0, &batch, &labels, 0.2).loss;
    let mut last = first;
    for _ in 0..20 {
        last = pjrt.step(&x0, &batch, &labels, 0.2).loss;
    }
    assert!(last < first * 0.7, "loss {first} -> {last}");
}
