//! Property-based tests over the coordinator's core invariants
//! (via `util::proptest_lite` — deterministic randomized cases).

use rapidgnn::cache::{device_memory_bound, top_hot, CacheBuffer, DoubleBufferCache};
use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
use rapidgnn::coordinator;
use rapidgnn::graph::{build_dataset, CsrGraph};
use rapidgnn::partition::{metis_like, partition_quality, random};
use rapidgnn::sampler::seed::Rng;
use rapidgnn::sampler::{
    enumerate_epoch, remote_frequency, sample_blocks, sample_input_nodes, Fanout,
};
use rapidgnn::sim::{pipeline_schedule, PipelineStep};
use rapidgnn::util::proptest_lite::{forall, gen};

/// Random small graph for structural properties.
fn random_graph(rng: &mut Rng) -> CsrGraph {
    let n = gen::usize_in(rng, 10, 400) as u32;
    let m = gen::usize_in(rng, n as usize, n as usize * 6);
    let edges: Vec<(u32, u32)> = (0..m)
        .filter_map(|_| {
            let u = rng.below(n);
            let v = rng.below(n);
            (u != v).then_some((u, v))
        })
        .collect();
    CsrGraph::from_edges(n, &edges)
}

#[test]
fn prop_pipeline_schedule_bounds() {
    // For any costs and queue depth: makespan ≥ Σ consume (work conservation),
    // ≤ the fully serial schedule, and deeper queues never hurt.
    forall(
        0xB01,
        300,
        |rng| {
            let n = gen::usize_in(rng, 1, 60);
            let steps: Vec<PipelineStep> = (0..n)
                .map(|_| PipelineStep {
                    stage: gen::f64_in(rng, 0.0, 2.0),
                    consume: gen::f64_in(rng, 0.01, 2.0),
                })
                .collect();
            let q = gen::usize_in(rng, 1, 10) as u32;
            (steps, q)
        },
        |(steps, q)| {
            let t = pipeline_schedule(steps, *q);
            let serial: f64 = steps.iter().map(|s| s.stage + s.consume).sum();
            let sum_consume: f64 = steps.iter().map(|s| s.consume).sum();
            if t.total > serial + 1e-9 {
                return Err(format!("worse than serial: {} > {serial}", t.total));
            }
            if t.total + 1e-9 < sum_consume {
                return Err(format!("faster than consume sum: {} < {sum_consume}", t.total));
            }
            if t.total_wait < -1e-12 {
                return Err("negative wait".into());
            }
            let deeper = pipeline_schedule(steps, q + 4);
            if deeper.total > t.total + 1e-9 {
                return Err(format!("deeper queue slower: {} > {}", deeper.total, t.total));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partitions_cover_all_nodes_exactly_once() {
    forall(
        11,
        40,
        |rng| (random_graph(rng), gen::usize_in(rng, 1, 8) as u32, rng.next_u64()),
        |(g, p, seed)| {
            for part in [metis_like(g, *p, *seed), random(g, *p, *seed)] {
                let total: usize = part.local_nodes.iter().map(Vec::len).sum();
                if total != g.num_nodes() as usize {
                    return Err(format!("covered {total} of {}", g.num_nodes()));
                }
                for (pi, locals) in part.local_nodes.iter().enumerate() {
                    for &v in locals {
                        if part.owner_of(v) != pi as u32 {
                            return Err(format!("node {v} owner mismatch"));
                        }
                    }
                }
                let q = partition_quality(g, &part);
                if !(0.0..=1.0).contains(&q.edge_cut_fraction) {
                    return Err(format!("cut fraction {}", q.edge_cut_fraction));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sampler_input_nodes_superset_of_seeds_sorted_unique() {
    forall(
        13,
        60,
        |rng| {
            let g = random_graph(rng);
            let n = g.num_nodes();
            let k = gen::usize_in(rng, 1, 32.min(n as usize));
            let seeds: Vec<u32> = (0..k).map(|_| rng.below(n)).collect();
            let f1 = gen::usize_in(rng, 1, 8) as u32;
            let f2 = gen::usize_in(rng, 1, 8) as u32;
            (g, seeds, [Fanout::Sample(f1), Fanout::Sample(f2)], rng.next_u64())
        },
        |(g, seeds, fanouts, seed)| {
            let ids = sample_input_nodes(g, seeds, fanouts, *seed);
            if !ids.windows(2).all(|w| w[0] < w[1]) {
                return Err("not sorted/unique".into());
            }
            for &s in seeds {
                if ids.binary_search(&s).is_err() {
                    return Err(format!("seed {s} missing from input nodes"));
                }
            }
            // trace path and block path agree
            let blocks = sample_blocks(g, seeds, fanouts, *seed);
            if blocks.node_layers[0] != ids {
                return Err("blocks/ids disagree".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_schedule_rpc_count_equals_miss_sets() {
    // Paper invariant (§3): "the per-step communication in an epoch equals
    // the miss set by the Prefetcher: the RPC count for b_i is |M_i^e|".
    // Empty cache ⇒ misses = all remote nodes; cache covering the epoch's
    // remote set ⇒ zero misses.
    forall(
        17,
        15,
        |rng| {
            let mut cfg = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
            cfg.gen_seed = rng.next_u64();
            (cfg, rng.next_u64())
        },
        |(dcfg, seed)| {
            let ds = build_dataset(dcfg, false);
            let part = std::sync::Arc::new(metis_like(&ds.graph, 2, 0));
            let shard: Vec<u32> = ds
                .train_nodes
                .iter()
                .copied()
                .filter(|&v| part.is_local(0, v))
                .collect();
            let sched = enumerate_epoch(
                &ds.graph,
                &part,
                &shard,
                &[Fanout::Sample(4), Fanout::Sample(4)],
                64,
                *seed,
                0,
                0,
            );
            let kv = rapidgnn::kvstore::KvStore::new(
                &ds,
                part,
                rapidgnn::net::NetFabric::new(Default::default()),
            );
            let empty = std::sync::Mutex::new(DoubleBufferCache::default());
            let mut stats = Default::default();
            for meta in sched.batches.iter().cloned() {
                let expect = meta.num_remote;
                let s = rapidgnn::prefetch::stage_batch(&kv, &empty, meta, 0, false, &mut stats);
                if s.misses != expect {
                    return Err(format!("empty cache: misses {} != remote {expect}", s.misses));
                }
            }
            let all_remote = top_hot(&sched.batches, u32::MAX);
            let full = std::sync::Mutex::new({
                let mut c = DoubleBufferCache::default();
                c.install_steady(CacheBuffer::new(&all_remote, Vec::new(), 16));
                c
            });
            for meta in sched.batches.iter().cloned() {
                let s = rapidgnn::prefetch::stage_batch(&kv, &full, meta, 0, false, &mut stats);
                if s.misses != 0 {
                    return Err(format!("full cache still missed {}", s.misses));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_top_hot_is_optimal_prefix() {
    // top_hot(k) must contain k highest-frequency remote nodes: any node
    // outside the selection has frequency ≤ the minimum inside it.
    forall(
        19,
        15,
        |rng| {
            let mut cfg = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
            cfg.gen_seed = rng.next_u64();
            (cfg, rng.below(500) + 1)
        },
        |(dcfg, k)| {
            let ds = build_dataset(dcfg, false);
            let part = std::sync::Arc::new(metis_like(&ds.graph, 2, 0));
            let shard: Vec<u32> = ds
                .train_nodes
                .iter()
                .copied()
                .filter(|&v| part.is_local(0, v))
                .collect();
            let sched = enumerate_epoch(
                &ds.graph,
                &part,
                &shard,
                &[Fanout::Sample(5), Fanout::Sample(5)],
                64,
                3,
                0,
                0,
            );
            let freq = remote_frequency(&sched.batches);
            let hot = top_hot(&sched.batches, *k);
            if hot.len() > *k as usize {
                return Err("over-selected".into());
            }
            let table: std::collections::BTreeMap<u32, u32> = freq.iter().copied().collect();
            let min_in = hot.iter().map(|v| table[v]).min().unwrap_or(0);
            let hotset: std::collections::BTreeSet<u32> = hot.iter().copied().collect();
            for &(v, c) in &freq {
                if !hotset.contains(&v) && c > min_in {
                    return Err(format!("node {v} freq {c} beats selected min {min_in}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_memory_bound_monotone() {
    forall(
        23,
        200,
        |rng| {
            (
                rng.below(100_000),
                rng.below(32) + 1,
                rng.below(100_000) + 1,
                rng.below(1_000) + 1,
            )
        },
        |&(n_hot, q, m_max, d)| {
            let base = device_memory_bound(n_hot, q, m_max, d);
            if device_memory_bound(n_hot + 1, q, m_max, d) < base
                || device_memory_bound(n_hot, q + 1, m_max, d) < base
                || device_memory_bound(n_hot, q, m_max + 1, d) < base
            {
                return Err("bound not monotone".into());
            }
            let expect = (2 * n_hot as u64 + q as u64 * m_max as u64) * d as u64 * 4;
            if base != expect {
                return Err(format!("formula mismatch {base} vs {expect}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_engine_reports_are_internally_consistent() {
    // Across random small run configs: steps > 0, times non-negative,
    // cache hits ≤ lookups, remote rows ≥ vector rows, epochs complete.
    forall(
        29,
        8,
        |rng| {
            let mut cfg = RunConfig::default();
            cfg.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
            cfg.dataset.gen_seed = rng.next_u64();
            cfg.engine = Engine::ALL[gen::usize_in(rng, 0, 3)];
            cfg.num_workers = rng.below(3) + 1;
            cfg.batch_size = [32u32, 64, 128][gen::usize_in(rng, 0, 2)];
            cfg.epochs = rng.below(3) + 1;
            cfg.n_hot = rng.below(500) + 1;
            cfg.prefetch_q = rng.below(8) + 1;
            cfg
        },
        |cfg| {
            let r = coordinator::run(cfg).map_err(|e| e.to_string())?;
            if r.epochs.len() != (cfg.epochs * cfg.num_workers) as usize {
                return Err(format!(
                    "expected {} epoch reports, got {}",
                    cfg.epochs * cfg.num_workers,
                    r.epochs.len()
                ));
            }
            for e in &r.epochs {
                if e.steps == 0 {
                    return Err("zero steps".into());
                }
                if e.epoch_time < 0.0 || e.phases.total() < 0.0 {
                    return Err("negative time".into());
                }
                if e.cache.hits > e.cache.lookups {
                    return Err("hits > lookups".into());
                }
                if e.comm.vector_rows > e.comm.remote_rows {
                    return Err("vector rows > remote rows".into());
                }
            }
            Ok(())
        },
    );
}
