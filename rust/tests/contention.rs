//! Contention-subsystem conformance: shared-link queueing determinism, the
//! linear-price lower bound, per-link conservation, the fat-tree/dragonfly
//! presets, and the transient-straggler phase axis.

use rapidgnn::config::{
    DatasetConfig, DatasetPreset, Engine, ExecMode, RunConfig, SpeedPhase, Topology,
};
use rapidgnn::coordinator;
use rapidgnn::util::proptest_lite::{forall, gen};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// One test mutates the process-global `RAPIDGNN_THREADS`; serialize every
/// test that renders runs so none races the env mutation.
fn env_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn tiny_cfg(engine: Engine) -> RunConfig {
    let mut c = RunConfig::default();
    c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
    c.engine = engine;
    c.epochs = 2;
    c.n_hot = 300;
    c
}

fn contended(mut c: RunConfig, topo: Topology) -> RunConfig {
    c.fabric.topology = topo;
    c.fabric.contention = true;
    c
}

#[test]
fn default_mode_emits_no_link_telemetry() {
    let _guard = env_lock();
    // The golden-trace byte-stability contract for contention = false: the
    // default config takes the untouched run_worker path and its serialized
    // report has no `links` key at all.
    let cfg = tiny_cfg(Engine::Rapid);
    assert!(!cfg.fabric.contention, "contention must default off");
    let r = coordinator::run(&cfg).unwrap();
    assert!(r.links.is_empty());
    assert!(!r.to_json().contains("\"links\""));
    // explicitly setting the flag to false is the identical run
    let mut off = tiny_cfg(Engine::Rapid);
    off.fabric.contention = false;
    assert_eq!(
        coordinator::run(&off).unwrap().to_json(),
        r.to_json(),
        "contention = false must be byte-identical to the default"
    );
}

#[test]
fn contended_two_tier_run_never_beats_the_linear_price() {
    let _guard = env_lock();
    let topo = Topology::TwoTier { racks: 2, oversubscription: 8.0 };
    for engine in [Engine::Rapid, Engine::DglMetis] {
        let mut linear = tiny_cfg(engine);
        linear.fabric.topology = topo;
        let lin = coordinator::run(&linear).unwrap();
        let con = coordinator::run(&contended(tiny_cfg(engine), topo)).unwrap();
        // identical schedules → identical data movement, only time changes
        assert_eq!(lin.total_remote_rows(), con.total_remote_rows(), "{}", engine.id());
        assert_eq!(lin.sync_remote_rows(), con.sync_remote_rows(), "{}", engine.id());
        assert!(
            con.total_time >= lin.total_time - 1e-9,
            "{}: contended {} beat the linear price {}",
            engine.id(),
            con.total_time,
            lin.total_time
        );
    }
    // The on-demand baseline's concurrent cross-rack pulls genuinely queue
    // on the spine: strictly slower, not just equal. Four workers so the
    // rack uplinks (and each requester's NIC fan-out) are actually shared —
    // with one worker per rack every route is disjoint and nothing queues.
    let mut linear = tiny_cfg(Engine::DglMetis);
    linear.num_workers = 4;
    linear.fabric.topology = topo;
    let lin = coordinator::run(&linear).unwrap();
    let mut queued = contended(tiny_cfg(Engine::DglMetis), topo);
    queued.num_workers = 4;
    let con = coordinator::run(&queued).unwrap();
    assert_eq!(lin.total_remote_rows(), con.total_remote_rows());
    assert!(
        con.total_time > lin.total_time + 1e-12,
        "dgl-metis under 8x oversubscription must contend: {} !> {}",
        con.total_time,
        lin.total_time
    );
}

#[test]
fn link_utilization_is_reported_and_conserved() {
    let _guard = env_lock();
    let topo = Topology::TwoTier { racks: 2, oversubscription: 4.0 };
    let mut cfg = contended(tiny_cfg(Engine::DglMetis), topo);
    cfg.num_workers = 4;
    let r = coordinator::run(&cfg).unwrap();
    assert!(!r.links.is_empty(), "contended run must surface link telemetry");
    assert!(r.to_json().contains("\"links\""));
    let b = cfg.fabric.bandwidth_bytes_per_sec;
    for l in &r.links {
        assert!(l.busy_sec > 0.0, "{}: accounted links must have been busy", l.link);
        assert!(
            l.served_bytes <= l.capacity_bytes_per_sec * l.busy_sec * (1.0 + 1e-9),
            "{}: served {} exceeds capacity x busy {}",
            l.link,
            l.served_bytes,
            l.capacity_bytes_per_sec * l.busy_sec
        );
        assert!(l.peak_flows >= 1);
    }
    // ISSUE gate: Σ link busy-time ≥ Σ RPC serialized bytes / bandwidth.
    // dgl-metis has no vector pulls, so every charged byte went through the
    // contended links.
    let busy: f64 = r.links.iter().map(|l| l.busy_sec).sum();
    let bytes: u64 = r.epochs.iter().map(|e| e.comm.bytes).sum();
    assert!(
        busy >= bytes as f64 / b - 1e-9,
        "conservation: Σ busy {busy} < Σ bytes/bw {}",
        bytes as f64 / b
    );
    // every flow crossed its source NIC exactly once → host egress bytes
    // equal the charged bytes
    let egress: f64 = r
        .links
        .iter()
        .filter(|l| l.link.starts_with("host-up:"))
        .map(|l| l.served_bytes)
        .sum();
    assert!(
        (egress - bytes as f64).abs() < 1.0,
        "host egress {egress} != charged bytes {bytes}"
    );
}

#[test]
fn full_equals_trace_remote_rows_on_fat_tree_and_dragonfly() {
    let _guard = env_lock();
    // The per-engine full == trace equality gate on the two new presets —
    // with and without contention (both modes run the same event schedule).
    for topo in [
        Topology::FatTree { k: 4 },
        Topology::Dragonfly { groups: 2, routers: 2 },
    ] {
        for engine in coordinator::EngineRegistry::global().engines() {
            for contention in [false, true] {
                let mut trace = tiny_cfg(engine);
                trace.batch_size = 64;
                trace.fabric.topology = topo;
                trace.fabric.contention = contention;
                let mut full = trace.clone();
                full.exec_mode = ExecMode::Full;
                let rt = coordinator::run(&trace).unwrap();
                let rf = coordinator::run(&full).unwrap();
                let tag = format!("{} on {} contention={contention}", engine.id(), topo.id());
                assert_eq!(rt.total_remote_rows(), rf.total_remote_rows(), "{tag}");
                assert_eq!(rt.sync_remote_rows(), rf.sync_remote_rows(), "{tag}");
                assert!((rt.cache_hit_rate() - rf.cache_hit_rate()).abs() < 1e-12, "{tag}");
            }
        }
    }
}

#[test]
fn new_topologies_change_time_but_not_rows() {
    let _guard = env_lock();
    let flat = coordinator::run(&tiny_cfg(Engine::DglMetis)).unwrap();
    for topo in [
        Topology::FatTree { k: 4 },
        Topology::Dragonfly { groups: 2, routers: 2 },
    ] {
        let mut cfg = tiny_cfg(Engine::DglMetis);
        cfg.fabric.topology = topo;
        let r = coordinator::run(&cfg).unwrap();
        assert_eq!(
            r.total_remote_rows(),
            flat.total_remote_rows(),
            "{}: rows must be topology-invariant",
            topo.id()
        );
        assert!(
            r.total_time >= flat.total_time - 1e-12,
            "{}: multi-hop presets cannot be cheaper than the flat switch",
            topo.id()
        );
    }
}

#[test]
fn contended_runs_are_identical_across_thread_counts() {
    let _guard = env_lock();
    // The ISSUE's determinism pin, as a property over random fabrics: a
    // contended cluster run renders byte-identical reports at
    // RAPIDGNN_THREADS ∈ {1, 2, 8}.
    let prev = std::env::var("RAPIDGNN_THREADS").ok();
    let render = |cfg: &RunConfig| coordinator::run(cfg).unwrap().to_json();
    forall(
        0xC0_47E4D,
        4,
        |rng| {
            let topo = match gen::usize_in(rng, 0, 3) {
                0 => Topology::TwoTier {
                    racks: 2,
                    oversubscription: 1.0 + gen::f64_in(rng, 0.0, 15.0),
                },
                1 => Topology::FatTree { k: 2 + gen::usize_in(rng, 0, 2) as u32 },
                2 => Topology::Dragonfly {
                    groups: 2,
                    routers: 1 + gen::usize_in(rng, 0, 1) as u32,
                },
                _ => Topology::Star { hub: 0 },
            };
            let engine = if gen::usize_in(rng, 0, 1) == 0 {
                Engine::Rapid
            } else {
                Engine::DglMetis
            };
            let seed = gen::usize_in(rng, 1, 1000) as u64;
            (topo, engine, seed)
        },
        |&(topo, engine, seed)| {
            let mut cfg = contended(tiny_cfg(engine), topo);
            cfg.base_seed = seed;
            std::env::set_var("RAPIDGNN_THREADS", "1");
            let serial = render(&cfg);
            for threads in ["2", "8"] {
                std::env::set_var("RAPIDGNN_THREADS", threads);
                if render(&cfg) != serial {
                    return Err(format!(
                        "threads={threads} changed the contended report ({} on {})",
                        engine.id(),
                        topo.id()
                    ));
                }
            }
            Ok(())
        },
    );
    match prev {
        Some(v) => std::env::set_var("RAPIDGNN_THREADS", v),
        None => std::env::remove_var("RAPIDGNN_THREADS"),
    }
}

// ---------------------------------------------------------------------------
// Transient stragglers (fabric.worker_speed_phases)
// ---------------------------------------------------------------------------

#[test]
fn single_phase_degenerates_to_static_worker_speed_bit_exactly() {
    let _guard = env_lock();
    let mut phased = tiny_cfg(Engine::Rapid);
    phased.fabric.worker_speed_phases =
        vec![SpeedPhase { from_epoch: 0, speeds: vec![1.0, 3.0] }];
    let mut fixed = tiny_cfg(Engine::Rapid);
    fixed.fabric.worker_speed = vec![1.0, 3.0];
    let a = coordinator::run(&phased).unwrap();
    let b = coordinator::run(&fixed).unwrap();
    assert_eq!(
        a.to_json(),
        b.to_json(),
        "a single phase from epoch 0 must reproduce the static vector bit-exactly"
    );
}

#[test]
fn phase_switch_slows_only_the_later_epochs() {
    let _guard = env_lock();
    let mut cfg = tiny_cfg(Engine::DglMetis);
    cfg.epochs = 4;
    let clean = coordinator::run(&cfg).unwrap();
    let mut phased = cfg.clone();
    phased.fabric.worker_speed_phases =
        vec![SpeedPhase { from_epoch: 2, speeds: vec![1.0, 4.0] }];
    let r = coordinator::run(&phased).unwrap();
    assert_eq!(clean.total_remote_rows(), r.total_remote_rows(), "phases change time only");
    for e in &r.epochs {
        let c = clean
            .epochs
            .iter()
            .find(|x| x.worker == e.worker && x.epoch == e.epoch)
            .unwrap();
        if e.epoch < 2 {
            assert!(
                (e.epoch_time - c.epoch_time).abs() == 0.0,
                "w{} e{}: pre-switch epochs must be untouched",
                e.worker,
                e.epoch
            );
        } else if e.worker == 1 {
            assert!(
                e.epoch_time > 2.0 * c.epoch_time,
                "w1 e{}: transient straggler must slow it ({} !> 2x {})",
                e.epoch,
                e.epoch_time,
                c.epoch_time
            );
        } else {
            // the other worker pays only the straggler's link penalty
            assert!(e.epoch_time >= c.epoch_time - 1e-12);
        }
    }
}

#[test]
fn phases_compose_with_contention() {
    let _guard = env_lock();
    // Both axes at once: a contended two-tier run with a mid-run straggler
    // phase stays deterministic and moves the same rows as its clean twin.
    let topo = Topology::TwoTier { racks: 2, oversubscription: 4.0 };
    let mut cfg = contended(tiny_cfg(Engine::Rapid), topo);
    cfg.epochs = 3;
    cfg.fabric.worker_speed_phases =
        vec![SpeedPhase { from_epoch: 1, speeds: vec![2.0] }];
    let a = coordinator::run(&cfg).unwrap();
    let b = coordinator::run(&cfg).unwrap();
    assert_eq!(a.to_json(), b.to_json(), "deterministic across runs");
    let clean = coordinator::run(&contended({
        let mut c = tiny_cfg(Engine::Rapid);
        c.epochs = 3;
        c
    }, topo))
    .unwrap();
    assert_eq!(a.total_remote_rows(), clean.total_remote_rows());
    assert!(a.total_time > clean.total_time, "the phase must cost time");
}
