//! End-to-end tests for the communication-compression engine family:
//! degeneration pins (compression off ⇒ bit-exact rapid), the priced byte
//! savings, codec/engine composition, convergence under error feedback, and
//! thread-count determinism.

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, ExecMode, RunConfig};
use rapidgnn::coordinator;

fn tiny_cfg(engine: Engine) -> RunConfig {
    let mut c = RunConfig::default();
    c.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
    c.engine = engine;
    c.epochs = 3;
    c.n_hot = 400;
    c
}

/// reddit-sim at bench scale: feature_dim 602, where int8's 4x payload cut
/// clears the headline 3.5x gate with block headers included.
fn reddit_cfg(engine: Engine) -> RunConfig {
    let mut c = RunConfig::default();
    c.dataset = DatasetConfig::preset(DatasetPreset::RedditSim, 0.05);
    c.engine = engine;
    c.epochs = 2;
    c.n_hot = 2_000;
    c
}

#[test]
fn quant_pull_with_codec_none_is_bit_exact_rapid() {
    // The degeneration pin: an explicit `codec = "none"` disables the whole
    // compressed charge path, and every field of the run report — counters,
    // f64 times, energies — matches rapid bit for bit.
    let rapid = coordinator::run(&tiny_cfg(Engine::Rapid)).unwrap();
    let mut cfg = tiny_cfg(Engine::QuantPull);
    cfg.engine_params.codec = rapidgnn::compress::Codec::None;
    let mut quant = coordinator::run(&cfg).unwrap();
    assert!(quant.compression.is_none(), "codec=none must not emit telemetry");
    quant.engine = rapid.engine.clone();
    assert_eq!(quant.to_json(), rapid.to_json());
}

#[test]
fn grad_topk_with_k_zero_is_bit_exact_rapid_in_full_mode() {
    let mk = |engine: Engine| {
        let mut c = tiny_cfg(engine);
        c.exec_mode = ExecMode::Full;
        c.batch_size = 64;
        c
    };
    let rapid = coordinator::run(&mk(Engine::Rapid)).unwrap();
    let mut cfg = mk(Engine::GradTopk);
    cfg.engine_params.grad_k = 0.0;
    let mut topk = coordinator::run(&cfg).unwrap();
    assert!(topk.compression.is_none(), "grad_k=0 must not emit telemetry");
    topk.engine = rapid.engine.clone();
    assert_eq!(topk.to_json(), rapid.to_json());
}

#[test]
fn quant_pull_int8_cuts_remote_feature_bytes_without_touching_rows() {
    // The headline acceptance gate: int8 at the default 128-element block
    // moves ≥ 3.5x fewer modeled remote feature bytes than rapid (headers
    // included) while `remote_rows` stays exactly codec-invariant.
    let rapid = coordinator::run(&reddit_cfg(Engine::Rapid)).unwrap();
    let quant = coordinator::run(&reddit_cfg(Engine::QuantPull)).unwrap();
    assert_eq!(
        quant.total_remote_rows(),
        rapid.total_remote_rows(),
        "compression must never change which rows move"
    );
    assert_eq!(quant.sync_remote_rows(), rapid.sync_remote_rows());
    let c = quant.compression.as_ref().expect("quant-pull reports telemetry");
    assert_eq!(c.codec, "int8");
    // d=602, block=128: payload 602 + 5·8 = 642 vs 2408 raw → exactly 3.75x.
    assert!(
        c.effective_compression_ratio >= 3.5,
        "payload ratio {} < 3.5",
        c.effective_compression_ratio
    );
    let row_bytes = reddit_cfg(Engine::Rapid).dataset.feature_row_bytes();
    assert_eq!(c.uncompressed_bytes, quant.total_remote_rows() * row_bytes);
    assert_eq!(c.bytes_saved, c.uncompressed_bytes - c.compressed_bytes);
    // Whole-run fabric bytes (with per-RPC envelopes) still clear 3x.
    let bytes = |r: &rapidgnn::metrics::RunReport| -> u64 {
        r.epochs.iter().map(|e| e.comm.bytes).sum()
    };
    let ratio = bytes(&rapid) as f64 / bytes(&quant) as f64;
    assert!(ratio >= 3.0, "fabric byte ratio {ratio} < 3.0");
    // Cheaper bytes ⇒ cheaper (never worse) modeled time.
    assert!(quant.total_time <= rapid.total_time);
    // rapid itself reports no compression block at all.
    assert!(rapid.compression.is_none());
    assert!(!rapid.to_json().contains("compression"));
    assert!(quant.to_json().contains("effective_compression_ratio"));
}

#[test]
fn trace_mode_reports_zero_quant_mse_and_full_mode_nonzero() {
    // Trace mode never materializes rows, so the error accumulator stays 0;
    // full mode round-trips real features and must observe real error.
    let trace = coordinator::run(&tiny_cfg(Engine::QuantPull)).unwrap();
    let tc = trace.compression.as_ref().unwrap();
    assert_eq!(tc.quant_mse, 0.0);
    assert!(tc.compressed_bytes > 0 && tc.compressed_bytes < tc.uncompressed_bytes);
    let mut full_cfg = tiny_cfg(Engine::QuantPull);
    full_cfg.exec_mode = ExecMode::Full;
    full_cfg.batch_size = 64;
    let full = coordinator::run(&full_cfg).unwrap();
    let fc = full.compression.as_ref().unwrap();
    assert!(fc.quant_mse > 0.0, "real features must quantize with real error");
    // Byte accounting is mode-invariant (same pulls, same payload math).
    assert_eq!(tc.compressed_bytes, fc.compressed_bytes);
    assert_eq!(tc.uncompressed_bytes, fc.uncompressed_bytes);
    // Dequantized features still train: loss decreases across epochs.
    let losses = full.loss_curve();
    assert!(losses.last().unwrap().1 < losses[0].1, "{losses:?}");
}

#[test]
fn explicit_codec_composes_with_green_window() {
    // The shared-knob composition: `codec = "int8"` on green-window charges
    // its merged window pulls at compressed payloads — same rows, fewer
    // bytes, faster — without any engine-specific wiring.
    let plain = coordinator::run(&reddit_cfg(Engine::GreenWindow)).unwrap();
    let mut cfg = reddit_cfg(Engine::GreenWindow);
    cfg.engine_params.codec = rapidgnn::compress::Codec::Int8;
    let compressed = coordinator::run(&cfg).unwrap();
    assert_eq!(compressed.total_remote_rows(), plain.total_remote_rows());
    let bytes = |r: &rapidgnn::metrics::RunReport| -> u64 {
        r.epochs.iter().map(|e| e.comm.bytes).sum()
    };
    assert!(bytes(&compressed) < bytes(&plain));
    assert!(compressed.total_time <= plain.total_time);
    assert_eq!(compressed.compression.as_ref().unwrap().codec, "int8");
    assert!(plain.compression.is_none());
    // f16 composes too, at its flat 2x payload cut.
    let mut f16_cfg = reddit_cfg(Engine::GreenWindow);
    f16_cfg.engine_params.codec = rapidgnn::compress::Codec::F16;
    let f16 = coordinator::run(&f16_cfg).unwrap();
    assert_eq!(f16.total_remote_rows(), plain.total_remote_rows());
    assert!(bytes(&f16) < bytes(&plain));
    assert!(bytes(&f16) > bytes(&compressed), "f16 (2x) saves less than int8 (~4x)");
}

#[test]
fn grad_topk_error_feedback_tracks_dense_convergence() {
    // Fig-9 style: error-fed top-k at k=10% lands near the dense run's final
    // loss (the strict 2% gate runs at bench scale; this pins the behaviour
    // at test scale) and reports its coordinate budget.
    let mk = |engine: Engine| {
        let mut c = tiny_cfg(engine);
        c.exec_mode = ExecMode::Full;
        c.batch_size = 64;
        c.epochs = 5;
        c
    };
    let dense = coordinator::run(&mk(Engine::Rapid)).unwrap();
    let sparse = coordinator::run(&mk(Engine::GradTopk)).unwrap();
    let final_loss = |r: &rapidgnn::metrics::RunReport| r.loss_curve().last().unwrap().1;
    let (ld, ls) = (final_loss(&dense), final_loss(&sparse));
    assert!(
        (ls - ld).abs() / ld < 0.15,
        "EF top-k final loss {ls} strays from dense {ld}"
    );
    // It genuinely trains (not just "close because nothing moved").
    let curve = sparse.loss_curve();
    assert!(curve.last().unwrap().1 < curve[0].1, "{curve:?}");
    let c = sparse.compression.as_ref().expect("grad-topk reports telemetry");
    assert_eq!(c.codec, "none", "grad-topk compresses gradients, not features");
    assert!(c.grad_elems_total > 0);
    let ratio = c.grad_elems_sent as f64 / c.grad_elems_total as f64;
    assert!(ratio > 0.05 && ratio < 0.2, "coordinate ratio {ratio} at k=0.1");
    // Identical traffic to rapid: gradients compress at the trainer, not the
    // fabric (the modeled all-reduce is out of scope for the kvstore path).
    assert_eq!(sparse.total_remote_rows(), dense.total_remote_rows());
}

#[test]
fn rand_k_differs_from_top_k_but_both_converge() {
    let mk = |mode: rapidgnn::compress::GradMode| {
        let mut c = tiny_cfg(Engine::GradTopk);
        c.exec_mode = ExecMode::Full;
        c.batch_size = 64;
        c.epochs = 4;
        c.engine_params.grad_mode = mode;
        c.engine_params.grad_k = 0.2;
        c
    };
    let topk = coordinator::run(&mk(rapidgnn::compress::GradMode::TopK)).unwrap();
    let randk = coordinator::run(&mk(rapidgnn::compress::GradMode::RandK)).unwrap();
    assert_ne!(
        topk.loss_curve().last().unwrap().1.to_bits(),
        randk.loss_curve().last().unwrap().1.to_bits(),
        "selectors must actually differ"
    );
    for r in [&topk, &randk] {
        let curve = r.loss_curve();
        assert!(curve.last().unwrap().1 < curve[0].1, "{curve:?}");
        assert!(curve.iter().all(|&(_, l)| l.is_finite()));
    }
}

#[test]
fn compression_engines_are_thread_count_invariant() {
    // The bit-determinism contract extends to the new engines: identical
    // serialized reports at RAPIDGNN_THREADS ∈ {1, 2, 8}. (Reports are
    // thread-count invariant by that same contract, so concurrently running
    // tests are unaffected by this env churn.)
    let run = |engine: Engine| {
        let mut c = tiny_cfg(engine);
        c.exec_mode = ExecMode::Full;
        c.batch_size = 64;
        c.epochs = 2;
        coordinator::run(&c).unwrap().to_json()
    };
    let prev = std::env::var("RAPIDGNN_THREADS").ok();
    for engine in [Engine::QuantPull, Engine::GradTopk] {
        std::env::set_var("RAPIDGNN_THREADS", "1");
        let serial = run(engine);
        for threads in ["2", "8"] {
            std::env::set_var("RAPIDGNN_THREADS", threads);
            assert_eq!(
                serial,
                run(engine),
                "{}: threads={threads} changed the report",
                engine.id()
            );
        }
    }
    match prev {
        Some(v) => std::env::set_var("RAPIDGNN_THREADS", v),
        None => std::env::remove_var("RAPIDGNN_THREADS"),
    }
}

#[test]
fn quant_pull_survives_the_toml_round_trip() {
    // CLI/TOML plumbing end to end: save a compression config, load it back,
    // run it, and get the same report.
    let dir = rapidgnn::util::tempdir::TempDir::new("compress-toml").unwrap();
    let path = dir.path().join("run.toml");
    let mut cfg = tiny_cfg(Engine::QuantPull);
    cfg.engine_params.codec = rapidgnn::compress::Codec::Int8;
    cfg.engine_params.codec_block = 64;
    cfg.engine_params.grad_k = 0.25;
    cfg.engine_params.grad_mode = rapidgnn::compress::GradMode::RandK;
    rapidgnn::config::save_run_config(&cfg, &path).unwrap();
    let loaded = rapidgnn::config::load_run_config(&path).unwrap();
    assert_eq!(loaded.engine_params, cfg.engine_params);
    assert_eq!(loaded.engine, Engine::QuantPull);
    let a = coordinator::run(&cfg).unwrap();
    let b = coordinator::run(&loaded).unwrap();
    assert_eq!(a.to_json(), b.to_json());
}
