"""AOT pipeline tests: lowering produces valid HLO text + manifests."""

import json
import os

import jax
import pytest

from compile.aot import PRESETS, build, make_caps, to_hlo_text
from compile.model import example_args, train_step


def test_caps_formula():
    b, n1, n0 = make_caps(128, 10, 25)
    assert b == 128
    assert n1 == 128 * 26
    assert n0 % 8 == 0 and n0 >= n1 * 11 - 8


def test_caps_round_non_aligned_batch():
    b, n1, n0 = make_caps(100, 3, 3)
    assert b == 104  # rounded to tile
    assert n1 % 8 == 0 and n0 % 8 == 0


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_lowering_all_presets_produces_hlo(tmp_path, preset):
    meta = build(preset, str(tmp_path))
    hlo_path = tmp_path / meta["hlo"]
    assert hlo_path.exists()
    text = hlo_path.read_text()
    assert text.startswith("HloModule"), text[:50]
    # the train step's tuple has 8 outputs (6 params + loss + correct)
    assert "tuple(" in text or "tuple (" in text
    # manifest is self-consistent
    loaded = json.loads((tmp_path / f"sage_{preset}.meta.json").read_text())
    assert loaded == meta
    assert loaded["n0_cap"] % 8 == 0


def test_hlo_has_no_custom_calls():
    """interpret=True must lower Pallas to plain HLO (no Mosaic custom-call
    the CPU PJRT client would reject)."""
    d, h, c, f1, f2, batch = PRESETS["tiny"]
    b_cap, n1_cap, n0_cap = make_caps(batch, f1, f2)
    args = example_args(d, h, c, f1, f2, b_cap, n1_cap, n0_cap)
    text = to_hlo_text(jax.jit(train_step).lower(*args))
    assert "custom-call" not in text, "Mosaic custom-call leaked into HLO"


def test_deterministic_lowering(tmp_path):
    a = build("tiny", str(tmp_path / "a"))
    b = build("tiny", str(tmp_path / "b"))
    assert a["b_cap"] == b["b_cap"]
    ta = (tmp_path / "a" / a["hlo"]).read_text()
    tb = (tmp_path / "b" / b["hlo"]).read_text()
    assert ta == tb, "lowering must be reproducible"
