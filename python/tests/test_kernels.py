"""Kernel vs reference oracle — the core L1 correctness signal.

Includes randomized shape sweeps (hypothesis-style: many generated cases,
deterministic seeds) and gradient checks through the custom VJPs.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels.matmul import matmul, vmem_bytes as mm_vmem
from compile.kernels.ref import masked_mean_ref, matmul_ref
from compile.kernels.sage_agg import masked_mean, vmem_bytes as agg_vmem, TILE_M


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


def rand_mask(rng, m, f):
    # ragged neighborhoods: some rows full, some partial, some empty
    mask = (rng.random((m, f)) < rng.random((m, 1)) * 1.2).astype(np.float32)
    return jnp.asarray(mask)


# ---------------------------------------------------------------- masked_mean

@pytest.mark.parametrize("m,f,d", [(8, 4, 16), (16, 10, 32), (8, 25, 602), (64, 1, 7)])
def test_masked_mean_matches_ref(m, f, d):
    rng = np.random.default_rng(m * 1000 + f * 10 + d)
    x = rand(rng, m, f, d)
    mask = rand_mask(rng, m, f)
    got = masked_mean(x, mask)
    want = masked_mean_ref(x, mask)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_masked_mean_shape_sweep():
    """Randomized sweep over (M, F, D) — hypothesis-style generation."""
    rng = np.random.default_rng(7)
    for case in range(25):
        m = TILE_M * int(rng.integers(1, 12))
        f = int(rng.integers(1, 30))
        d = int(rng.integers(1, 130))
        x = rand(rng, m, f, d)
        mask = rand_mask(rng, m, f)
        np.testing.assert_allclose(
            masked_mean(x, mask), masked_mean_ref(x, mask),
            rtol=1e-5, atol=1e-5, err_msg=f"case {case}: m={m} f={f} d={d}",
        )


def test_masked_mean_empty_rows_are_zero():
    x = jnp.ones((8, 4, 5), jnp.float32)
    mask = jnp.zeros((8, 4), jnp.float32)
    out = masked_mean(x, mask)
    np.testing.assert_array_equal(out, np.zeros((8, 5), np.float32))


def test_masked_mean_full_mask_is_plain_mean():
    rng = np.random.default_rng(3)
    x = rand(rng, 16, 6, 12)
    mask = jnp.ones((16, 6), jnp.float32)
    np.testing.assert_allclose(masked_mean(x, mask), jnp.mean(x, axis=1), rtol=1e-5, atol=1e-6)


def test_masked_mean_rejects_unpadded_m():
    x = jnp.ones((9, 4, 5), jnp.float32)  # 9 not multiple of 8
    mask = jnp.ones((9, 4), jnp.float32)
    with pytest.raises(AssertionError):
        masked_mean(x, mask)


def test_masked_mean_gradient_matches_ref_gradient():
    rng = np.random.default_rng(11)
    x = rand(rng, 16, 5, 20)
    mask = rand_mask(rng, 16, 5)

    def f_kernel(x):
        return jnp.sum(jnp.sin(masked_mean(x, mask)))

    def f_ref(x):
        return jnp.sum(jnp.sin(masked_mean_ref(x, mask)))

    gk = jax.grad(f_kernel)(x)
    gr = jax.grad(f_ref)(x)
    np.testing.assert_allclose(gk, gr, rtol=1e-5, atol=1e-5)


def test_masked_mean_vmem_under_budget():
    # Worst artifact config: F=25, D=602 → block must fit VMEM (~16 MiB)
    assert agg_vmem(25, 602) < 16 * 2**20


# -------------------------------------------------------------------- matmul

@pytest.mark.parametrize("m,k,n", [(8, 16, 4), (32, 602, 64), (64, 64, 172), (8, 1, 1)])
def test_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m + k + n)
    x = rand(rng, m, k)
    w = rand(rng, k, n)
    np.testing.assert_allclose(matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_shape_sweep():
    rng = np.random.default_rng(13)
    for case in range(25):
        m = TILE_M * int(rng.integers(1, 16))
        k = int(rng.integers(1, 300))
        n = int(rng.integers(1, 100))
        x = rand(rng, m, k)
        w = rand(rng, k, n)
        np.testing.assert_allclose(
            matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-4,
            err_msg=f"case {case}: m={m} k={k} n={n}",
        )


def test_matmul_gradients():
    rng = np.random.default_rng(17)
    x = rand(rng, 16, 12)
    w = rand(rng, 12, 5)

    def f(x, w):
        return jnp.sum(matmul(x, w) ** 2)

    def f_ref(x, w):
        return jnp.sum(matmul_ref(x, w) ** 2)

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)


def test_matmul_vmem_under_budget():
    assert mm_vmem(602, 172) < 16 * 2**20


def test_kernels_compose_under_jit():
    """The composition the model uses, under jit (the lowering path)."""
    rng = np.random.default_rng(19)
    x = rand(rng, 16, 6, 10)
    mask = rand_mask(rng, 16, 6)
    w = rand(rng, 10, 4)

    @jax.jit
    def f(x, mask, w):
        return matmul(masked_mean(x, mask), w)

    got = f(x, mask, w)
    want = matmul_ref(masked_mean_ref(x, mask), w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
