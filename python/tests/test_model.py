"""L2 model tests: gradient correctness, padding invariance, training signal."""

import numpy as np
import jax
import jax.numpy as jnp

from compile.model import train_step, _sage_layer, _loss_and_correct
from compile.aot import make_caps


def make_inputs(rng, d=12, h=8, c=4, f1=3, f2=2, b=8, n1=16, n0=40, valid_b=None):
    """Random but self-consistent padded batch."""
    f32, i32 = np.float32, np.int32
    valid_b = b if valid_b is None else valid_b
    params = [
        rng.standard_normal((d, h)).astype(f32) * 0.2,
        rng.standard_normal((d, h)).astype(f32) * 0.2,
        np.zeros(h, f32),
        rng.standard_normal((h, c)).astype(f32) * 0.2,
        rng.standard_normal((h, c)).astype(f32) * 0.2,
        np.zeros(c, f32),
    ]
    x0 = rng.standard_normal((n0, d)).astype(f32)
    self1 = rng.integers(0, n0, n1).astype(i32)
    nbr1 = rng.integers(0, n0, (n1, f1)).astype(i32)
    m1 = (rng.random((n1, f1)) < 0.8).astype(f32)
    self2 = rng.integers(0, n1, b).astype(i32)
    nbr2 = rng.integers(0, n1, (b, f2)).astype(i32)
    m2 = (rng.random((b, f2)) < 0.8).astype(f32)
    labels = rng.integers(0, c, b).astype(i32)
    lmask = np.zeros(b, f32)
    lmask[:valid_b] = 1.0
    return params, (x0, self1, nbr1, m1, self2, nbr2, m2, labels, lmask)


def run_step(params, batch, lr=0.1):
    return train_step(*params, jnp.float32(lr), *batch)


def test_step_output_shapes():
    rng = np.random.default_rng(1)
    params, batch = make_inputs(rng)
    out = run_step(params, batch)
    assert len(out) == 8
    for new, old in zip(out[:6], params):
        assert new.shape == old.shape
    loss, correct = out[6], out[7]
    assert loss.shape == () and correct.shape == ()
    assert np.isfinite(float(loss))


def test_loss_decreases_on_repeated_steps():
    rng = np.random.default_rng(2)
    params, batch = make_inputs(rng)
    step = jax.jit(train_step)
    losses = []
    p = [jnp.asarray(x) for x in params]
    for _ in range(30):
        out = step(*p, jnp.float32(0.2), *batch)
        p = list(out[:6])
        losses.append(float(out[6]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_zero_lr_leaves_params_unchanged():
    rng = np.random.default_rng(3)
    params, batch = make_inputs(rng)
    out = run_step(params, batch, lr=0.0)
    for new, old in zip(out[:6], params):
        np.testing.assert_array_equal(np.asarray(new), old)


def test_masked_seeds_get_no_gradient():
    """Padding seeds (label_mask 0) must not change the loss or grads."""
    rng = np.random.default_rng(4)
    params, batch = make_inputs(rng, valid_b=4)
    x0, self1, nbr1, m1, self2, nbr2, m2, labels, lmask = batch
    out1 = run_step(params, batch)
    # change the labels of MASKED rows — nothing should move
    labels2 = labels.copy()
    labels2[4:] = (labels2[4:] + 1) % 4
    batch2 = (x0, self1, nbr1, m1, self2, nbr2, m2, labels2, lmask)
    out2 = run_step(params, batch2)
    np.testing.assert_allclose(float(out1[6]), float(out2[6]), rtol=1e-6)
    for a, b in zip(out1[:6], out2[:6]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_gradients_match_numerical():
    rng = np.random.default_rng(5)
    params, batch = make_inputs(rng)

    def loss_of(params_flat):
        out = run_step(params_flat, batch, lr=0.0)
        return float(out[6])

    # analytic grad from the SGD update at lr=1: g = p - p'
    out = run_step(params, batch, lr=1.0)
    g_w1 = params[0] - np.asarray(out[0])
    eps = 1e-3
    for idx in [(0, 0), (3, 2), (11, 7)]:
        p2 = [p.copy() for p in params]
        p2[0][idx] += eps
        lp = loss_of(p2)
        p2[0][idx] -= 2 * eps
        lm = loss_of(p2)
        numeric = (lp - lm) / (2 * eps)
        assert abs(numeric - g_w1[idx]) < 5e-3, (idx, numeric, g_w1[idx])


def test_correct_count_bounded_by_valid():
    rng = np.random.default_rng(6)
    params, batch = make_inputs(rng, valid_b=5)
    out = run_step(params, batch)
    assert 0 <= float(out[7]) <= 5


def test_make_caps_are_tile_aligned():
    for batch, f1, f2 in [(128, 10, 25), (256, 5, 10), (1, 1, 1), (1000, 10, 25)]:
        b, n1, n0 = make_caps(batch, f1, f2)
        assert b % 8 == 0 and n1 % 8 == 0 and n0 % 8 == 0
        assert b >= batch
        assert n1 >= b * (1 + f2) - 8
        assert n0 >= n1 * (1 + f1) - 8


def test_layer_and_loss_helpers():
    rng = np.random.default_rng(7)
    params, batch = make_inputs(rng)
    x0, self1, nbr1, m1, *_ = batch
    h1 = _sage_layer(jnp.asarray(x0), *[jnp.asarray(p) for p in params[:3]],
                     self1, nbr1, m1, relu=True)
    assert h1.shape == (16, 8)
    assert float(jnp.min(h1)) >= 0.0, "relu output"
    logits = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    labels = jnp.asarray([0, 1, 2, 3, 0, 1, 2, 3], jnp.int32)
    lmask = jnp.ones(8, jnp.float32)
    loss, correct = _loss_and_correct(logits, labels, lmask)
    assert np.isfinite(float(loss)) and 0 <= float(correct) <= 8
