"""AOT lowering: JAX train step (with Pallas kernels) → HLO text + manifest.

HLO *text* is the interchange format with the rust runtime: the image's
xla_extension 0.5.1 rejects jax≥0.5's serialized protos (64-bit instruction
ids), while the text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md.

Usage::

    python -m compile.aot --out-dir ../artifacts          # all presets
    python -m compile.aot --preset tiny --out-dir ../artifacts

Each artifact is ``sage_<preset>.hlo.txt`` plus ``sage_<preset>.meta.json``
describing the fixed shapes (the rust side matches on d/h/c/fanout/caps).
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import example_args, train_step

TILE = 8  # Pallas row-tile height; all caps padded to multiples of it.


def _round_up(x, m):
    return (x + m - 1) // m * m


def make_caps(batch, f1, f2):
    """Padded capacities for a 2-layer sampled batch (DGL fanout [f1, f2])."""
    b_cap = _round_up(batch, TILE)
    n1_cap = _round_up(b_cap * (1 + f2), TILE)
    n0_cap = _round_up(n1_cap * (1 + f1), TILE)
    return b_cap, n1_cap, n0_cap


# Preset name -> (d, h, c, f1, f2, batch). Matches the rust DatasetConfig
# presets (dims) and the example/test run configs (fanout, batch).
PRESETS = {
    # rust RunConfig::default() on the tiny dataset: fanout [10,25], batch 128
    "tiny": (16, 64, 4, 10, 25, 128),
    # e2e example: products-sim, fanout [5,10], batch 256
    "products": (100, 64, 47, 5, 10, 256),
    # reddit-sim with a reduced batch (d=602 rows are heavy)
    "reddit": (602, 64, 50, 5, 10, 128),
    # papers-sim
    "papers": (128, 64, 172, 5, 10, 256),
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Cap overrides: the generic formula assumes the k-hop expansion never
# saturates the graph; for small graphs the node count itself is the cap
# (perf: the tiny artifact's padded rows drop 36608 -> 2000, an ~18x cut in
# wasted gather/matmul work — see EXPERIMENTS.md §Perf).
CAP_OVERRIDES = {
    "tiny": (128, 2000, 2000),  # tiny graph has 2000 nodes total
}


def build(preset: str, out_dir: str) -> dict:
    d, h, c, f1, f2, batch = PRESETS[preset]
    b_cap, n1_cap, n0_cap = CAP_OVERRIDES.get(preset) or make_caps(batch, f1, f2)
    args = example_args(d, h, c, f1, f2, b_cap, n1_cap, n0_cap)
    lowered = jax.jit(train_step).lower(*args)
    hlo = to_hlo_text(lowered)

    os.makedirs(out_dir, exist_ok=True)
    hlo_name = f"sage_{preset}.hlo.txt"
    with open(os.path.join(out_dir, hlo_name), "w") as f:
        f.write(hlo)
    meta = {
        "hlo": hlo_name,
        "d": d,
        "h": h,
        "c": c,
        "f1": f1,
        "f2": f2,
        "b_cap": b_cap,
        "n1_cap": n1_cap,
        "n0_cap": n0_cap,
    }
    with open(os.path.join(out_dir, f"sage_{preset}.meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default=None,
                    help="single preset (default: all)")
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    presets = [args.preset] if args.preset else sorted(PRESETS)
    for p in presets:
        meta = build(p, args.out_dir)
        print(f"built sage_{p}: caps=({meta['b_cap']},{meta['n1_cap']},{meta['n0_cap']})"
              f" d={meta['d']} h={meta['h']} c={meta['c']}")


if __name__ == "__main__":
    main()
