"""Layer-2 JAX model: 2-layer GraphSAGE forward/backward/SGD train step.

Mirrors the rust host reference (`rust/src/trainer/sage.rs`) exactly:
``h_dst = relu(x_self @ W_self + masked_mean(x_nbrs) @ W_nbr + b)`` per layer,
softmax cross-entropy averaged over valid (mask=1) seeds, plain SGD. The
aggregation and the forward linear transforms run through the Pallas kernels
in :mod:`compile.kernels`.

The function signature is the operand-order contract with the rust runtime
(`rust/src/runtime/pjrt.rs`):

  inputs:  w_self1, w_nbr1, b1, w_self2, w_nbr2, b2, lr,
           x0, self1, nbr1, m1, self2, nbr2, m2, labels, label_mask
  outputs: (w_self1', w_nbr1', b1', w_self2', w_nbr2', b2', loss, correct)

All shapes are static (padded to the artifact caps); index padding rows are
masked out of the loss and receive no gradient.
"""

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul
from .kernels.sage_agg import masked_mean


def _sage_layer(src, w_self, w_nbr, b, self_idx, nbr_idx, mask, relu):
    """One SAGE layer over a sampled block."""
    x_self = jnp.take(src, self_idx, axis=0)  # [M, D]
    x_nbrs = jnp.take(src, nbr_idx, axis=0)  # [M, F, D]
    agg = masked_mean(x_nbrs, mask)  # [M, D]  (Pallas)
    z = matmul(x_self, w_self) + matmul(agg, w_nbr) + b  # (Pallas fwd)
    return jax.nn.relu(z) if relu else z


def _loss_and_correct(logits, labels, label_mask):
    """Masked mean softmax cross-entropy + correct count."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = logz - picked
    valid = jnp.maximum(jnp.sum(label_mask), 1.0)
    loss = jnp.sum(ce * label_mask) / valid
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == labels).astype(jnp.float32) * label_mask)
    return loss, correct


def train_step(
    w_self1,
    w_nbr1,
    b1,
    w_self2,
    w_nbr2,
    b2,
    lr,
    x0,
    self1,
    nbr1,
    m1,
    self2,
    nbr2,
    m2,
    labels,
    label_mask,
):
    """One SGD step. Returns (updated params..., loss, correct)."""
    params = (w_self1, w_nbr1, b1, w_self2, w_nbr2, b2)

    def loss_fn(params):
        ws1, wn1, bb1, ws2, wn2, bb2 = params
        h1 = _sage_layer(x0, ws1, wn1, bb1, self1, nbr1, m1, relu=True)
        logits = _sage_layer(h1, ws2, wn2, bb2, self2, nbr2, m2, relu=False)
        loss, correct = _loss_and_correct(logits, labels, label_mask)
        return loss, correct

    (loss, correct), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new_params, loss, correct)


def example_args(d, h, c, f1, f2, b_cap, n1_cap, n0_cap):
    """ShapeDtypeStructs matching the operand contract (for AOT lowering)."""
    f32 = jnp.float32
    i32 = jnp.int32
    S = jax.ShapeDtypeStruct
    return (
        S((d, h), f32),  # w_self1
        S((d, h), f32),  # w_nbr1
        S((h,), f32),  # b1
        S((h, c), f32),  # w_self2
        S((h, c), f32),  # w_nbr2
        S((c,), f32),  # b2
        S((), f32),  # lr
        S((n0_cap, d), f32),  # x0
        S((n1_cap,), i32),  # self1
        S((n1_cap, f1), i32),  # nbr1
        S((n1_cap, f1), f32),  # m1
        S((b_cap,), i32),  # self2
        S((b_cap, f2), i32),  # nbr2
        S((b_cap, f2), f32),  # m2
        S((b_cap,), i32),  # labels
        S((b_cap,), f32),  # label_mask
    )
