"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must agree with its reference here to float32
tolerance, across the full shape/dtype sweep in ``python/tests``.
"""

import jax.numpy as jnp


def masked_mean_ref(x_nbrs, mask):
    """Reference masked mean: x_nbrs [M,F,D], mask [M,F] -> [M,D]."""
    s = jnp.sum(x_nbrs * mask[:, :, None], axis=1)
    cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return s / cnt


def matmul_ref(x, w):
    """Reference matmul."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)
