"""Layer-1 Pallas kernel: tiled matmul for the SAGE linear transforms.

Tiles the row axis into ``(TM, K)`` VMEM blocks with the full weight matrix
``(K, N)`` resident (K ≤ 602, N ≤ 172 across every artifact config → worst
case 602×172×4 ≈ 405 KiB, comfortably inside a TPU core's ~16 MiB VMEM; see
``vmem_bytes``). The inner ``jnp.dot`` maps onto the MXU with
``preferred_element_type=f32`` accumulation.

Backward matmuls (``dx = dz @ W^T``, ``dW = x^T @ dz``) are delegated to XLA
via ``jnp.dot`` inside the custom VJP: their shapes transpose the row tiling
(K is not a multiple of the tile height for d=602), and XLA's native emitter
already saturates the MXU for plain GEMMs — the Pallas win is on the forward
path fused with the aggregation schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 8


def _mm_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def matmul(x, w):
    """``x [M,K] @ w [K,N]`` with M a multiple of TILE_M."""
    return _matmul_impl(x, w)


def _matmul_impl(x, w):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % TILE_M == 0, f"M={m} must be a multiple of {TILE_M} (pad caps)"
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // TILE_M,),
        in_specs=[
            pl.BlockSpec((TILE_M, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),  # weights replicated
        ],
        out_specs=pl.BlockSpec((TILE_M, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


def _matmul_fwd(x, w):
    return _matmul_impl(x, w), (x, w)


def _matmul_bwd(res, dz):
    x, w = res
    dx = jnp.dot(dz, w.T)
    dw = jnp.dot(x.T, dz)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(k: int, n: int) -> int:
    """Estimated VMEM footprint of one block (DESIGN.md §Perf)."""
    return TILE_M * k * 4 + k * n * 4 + TILE_M * n * 4
