"""Layer-1 Pallas kernels: masked-mean neighbor aggregation.

This is the GNN hot spot the whole paper is about feeding efficiently: given
the gathered neighbor features ``x_nbrs [M, F, D]`` and a validity mask
``[M, F]`` (sampled neighborhoods are ragged; RapidGNN pads to fan-out F),
compute the mean over valid neighbors per destination node.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's cluster
does this with CUDA gathers into GPU global memory; on TPU we tile the
destination axis into VMEM-resident blocks via ``BlockSpec`` — block shape
``(TM, F, D)`` with the full feature row in the lane dimension — and reduce
over the neighbor axis in-register. ``interpret=True`` everywhere: the CPU
PJRT plugin cannot execute Mosaic custom-calls, so the kernel lowers to plain
HLO that the rust runtime can run; real-TPU numbers are estimated from the
VMEM footprint in DESIGN.md §Perf.

The backward pass is its own Pallas kernel, wired up with ``jax.custom_vjp``
(Pallas calls are not auto-differentiable).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Destination-node tile: 8 sublanes is the native f32 tile height on TPU.
TILE_M = 8


def _fwd_kernel(x_ref, m_ref, o_ref):
    """One (TM, F, D) block: masked sum over F, divided by the valid count."""
    x = x_ref[...]  # [TM, F, D]
    m = m_ref[...]  # [TM, F]
    s = jnp.sum(x * m[:, :, None], axis=1)  # [TM, D]
    cnt = jnp.maximum(jnp.sum(m, axis=1, keepdims=True), 1.0)  # [TM, 1]
    o_ref[...] = s / cnt


def _bwd_kernel(dout_ref, m_ref, dx_ref):
    """dx[m, f, :] = dout[m, :] * mask[m, f] / count(m)."""
    g = dout_ref[...]  # [TM, D]
    m = m_ref[...]  # [TM, F]
    cnt = jnp.maximum(jnp.sum(m, axis=1, keepdims=True), 1.0)
    dx_ref[...] = (g / cnt)[:, None, :] * m[:, :, None]


def _grid(m):
    assert m % TILE_M == 0, f"M={m} must be a multiple of {TILE_M} (pad caps)"
    return (m // TILE_M,)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def masked_mean(x_nbrs, mask):
    """Mean over valid neighbor slots. x_nbrs [M,F,D] f32, mask [M,F] f32."""
    return _masked_mean_fwd_impl(x_nbrs, mask)


def _masked_mean_fwd_impl(x_nbrs, mask):
    m, f, d = x_nbrs.shape
    return pl.pallas_call(
        _fwd_kernel,
        grid=_grid(m),
        in_specs=[
            pl.BlockSpec((TILE_M, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((TILE_M, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x_nbrs.dtype),
        interpret=True,
    )(x_nbrs, mask)


def _masked_mean_fwd(x_nbrs, mask):
    return _masked_mean_fwd_impl(x_nbrs, mask), (mask, x_nbrs.shape)


def _masked_mean_bwd(res, dout):
    mask, (m, f, d) = res
    dx = pl.pallas_call(
        _bwd_kernel,
        grid=_grid(m),
        in_specs=[
            pl.BlockSpec((TILE_M, d), lambda i: (i, 0)),
            pl.BlockSpec((TILE_M, f), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, f, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, f, d), dout.dtype),
        interpret=True,
    )(dout, mask)
    # mask is structural (0/1 padding), not a trainable input: zero grad.
    return dx, jnp.zeros_like(mask)


masked_mean.defvjp(_masked_mean_fwd, _masked_mean_bwd)


def vmem_bytes(f: int, d: int) -> int:
    """Estimated VMEM footprint of one forward block (DESIGN.md §Perf)."""
    x_block = TILE_M * f * d * 4
    m_block = TILE_M * f * 4
    o_block = TILE_M * d * 4
    return x_block + m_block + o_block
