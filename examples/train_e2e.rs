//! End-to-end driver: full-stack training through every layer.
//!
//! Trains a 2-layer GraphSAGE on the products-sim dataset (OGBN-Products
//! shape: d=100, 47 classes) with the **PJRT backend** — the AOT-compiled
//! JAX model whose aggregation runs through the Pallas kernel — coordinated
//! by the RapidGNN engine (precomputed schedule, hot-set cache, threaded
//! prefetcher). Logs the loss/accuracy curve and communication stats;
//! results recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_e2e [epochs] [host|pjrt]
//! ```

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, ExecMode, RunConfig, TrainerBackend};
use rapidgnn::coordinator;
use rapidgnn::util::bench::{fmt_bytes, fmt_secs};
use rapidgnn::util::wallclock::Stopwatch;

fn main() -> rapidgnn::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let epochs: u32 = args.first().map_or(4, |s| s.parse().expect("epochs"));
    let backend = match args.get(1).map(String::as_str) {
        Some("host") => TrainerBackend::Host,
        _ => TrainerBackend::Pjrt,
    };

    let mut cfg = RunConfig::default();
    // products-sim at 1/4 scale keeps the e2e run under a couple of minutes
    // while still sampling a 30k-node power-law graph.
    cfg.dataset = DatasetConfig::preset(DatasetPreset::ProductsSim, 0.25);
    cfg.engine = Engine::Rapid;
    cfg.exec_mode = ExecMode::Full;
    cfg.backend = backend;
    cfg.num_workers = 2;
    cfg.batch_size = 256;
    cfg.fanout = vec![5, 10]; // matches the `products` artifact
    cfg.epochs = epochs;
    cfg.n_hot = 2_000;
    cfg.prefetch_q = 4;
    cfg.learning_rate = 0.08;

    println!(
        "e2e: RapidGNN + {:?} backend on {} ({} nodes, d={}, {} classes), {} epochs",
        cfg.backend,
        cfg.dataset.name,
        cfg.dataset.num_nodes,
        cfg.dataset.feature_dim,
        cfg.dataset.num_classes,
        cfg.epochs
    );

    let wall = Stopwatch::start();
    let report = coordinator::run(&cfg)?;
    let wall = wall.elapsed_sec();

    println!("\n  epoch |   loss | train acc | sim time | cache hit");
    println!("  ------+--------+-----------+----------+----------");
    let losses = report.loss_curve();
    let accs = report.accuracy_curve();
    for ((e, loss), (_, acc)) in losses.iter().zip(&accs) {
        let hits: u64 = report.epochs.iter().filter(|r| r.epoch == *e).map(|r| r.cache.hits).sum();
        let lookups: u64 =
            report.epochs.iter().filter(|r| r.epoch == *e).map(|r| r.cache.lookups).sum();
        let time: f64 = report
            .epochs
            .iter()
            .filter(|r| r.epoch == *e)
            .map(|r| r.epoch_time)
            .sum::<f64>()
            / report.num_workers as f64;
        println!(
            "  {e:>5} | {loss:>6.3} | {:>8.1}% | {:>8} | {:>8.1}%",
            acc * 100.0,
            fmt_secs(time),
            100.0 * hits as f64 / lookups.max(1) as f64
        );
    }

    let steps: u32 = report.epochs.iter().map(|e| e.steps).sum();
    println!(
        "\n  {} steps, {} total sim time (+{} setup), {:.1}s wall",
        steps,
        fmt_secs(report.total_time),
        fmt_secs(report.setup_time),
        wall
    );
    println!(
        "  comm: {} remote rows, {} moved, {} mean/step",
        report.total_remote_rows(),
        fmt_bytes(report.epochs.iter().map(|e| e.comm.bytes).sum::<u64>() as f64),
        fmt_bytes(report.mean_bytes_per_step()),
    );
    println!(
        "  energy: {:.0} J CPU, {:.0} J GPU",
        report.cpu_energy_j, report.gpu_energy_j
    );

    let first = losses.first().map(|&(_, l)| l).unwrap_or(f64::NAN);
    let last = losses.last().map(|&(_, l)| l).unwrap_or(f64::NAN);
    assert!(
        last < first,
        "loss must decrease over training: {first:.3} -> {last:.3}"
    );
    println!("\n  OK: loss decreased {first:.3} -> {last:.3}; all three layers composed.");
    Ok(())
}
