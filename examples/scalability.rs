//! Worker-count scaling: the interactive analogue of paper Fig. 6/7.
//!
//! Sweeps P = 1..8 workers on products-sim with both RapidGNN and DGL-METIS,
//! printing per-epoch time, speedup over P=2 (the paper's reference point),
//! and memory — near-linear scaling with flat CPU memory and bounded,
//! cache-dominated GPU memory.
//!
//! ```bash
//! cargo run --release --example scalability
//! ```

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
use rapidgnn::coordinator;
use rapidgnn::util::bench::{fmt_secs, Table};

fn main() -> rapidgnn::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.dataset = DatasetConfig::preset(DatasetPreset::ProductsSim, 0.3);
    cfg.batch_size = 512;
    cfg.epochs = 3;
    cfg.n_hot = 2_000;

    println!(
        "scalability on {} ({} nodes), batch {}",
        cfg.dataset.name, cfg.dataset.num_nodes, cfg.batch_size
    );

    for engine in [Engine::Rapid, Engine::DglMetis] {
        let mut t = Table::new(
            &format!("{} — scaling with workers", engine.name()),
            &["P", "epoch time", "speedup vs P=2", "device MB", "host MB"],
        );
        let mut p2_time = None;
        for p in [1u32, 2, 3, 4, 6, 8] {
            let mut c = cfg.clone();
            c.engine = engine;
            c.num_workers = p;
            let r = coordinator::run(&c)?;
            let epoch_time = r.total_time / c.epochs as f64;
            if p == 2 {
                p2_time = Some(epoch_time);
            }
            t.row(&[
                p.to_string(),
                fmt_secs(epoch_time),
                p2_time.map_or("-".into(), |t2| format!("{:.2}x", t2 / epoch_time)),
                format!("{:.1}", r.peak_device_bytes() as f64 / 1e6),
                format!("{:.1}", r.peak_host_bytes() as f64 / 1e6),
            ]);
        }
        t.print();
    }
    println!("(paper Fig. 6: 1.5-1.6x at P=3, 1.7-2.1x at P=4 over the P=2 baseline)");
    Ok(())
}
