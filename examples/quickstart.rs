//! Quickstart: build a synthetic graph, train two epochs with RapidGNN, and
//! compare against the DGL-METIS baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
use rapidgnn::coordinator;
use rapidgnn::util::bench::{fmt_bytes, fmt_secs};

fn main() -> rapidgnn::Result<()> {
    // 1. Describe the run: a tiny power-law graph, 2 workers, 2 epochs.
    let mut cfg = RunConfig::default();
    cfg.dataset = DatasetConfig::preset(DatasetPreset::Tiny, 1.0);
    cfg.num_workers = 2;
    cfg.epochs = 2;
    cfg.n_hot = 400; // hot-set cache entries per worker
    cfg.prefetch_q = 4; // batches staged ahead

    // 2. Train with RapidGNN (deterministic schedule + cache + prefetcher).
    cfg.engine = Engine::Rapid;
    let rapid = coordinator::run(&cfg)?;

    // 3. Train the same workload with the on-demand DistDGL-style baseline.
    cfg.engine = Engine::DglMetis;
    let baseline = coordinator::run(&cfg)?;

    // 4. Compare.
    println!("RapidGNN quickstart — {} ({} workers)", cfg.dataset.name, cfg.num_workers);
    for (name, r) in [("RapidGNN", &rapid), ("DGL-METIS", &baseline)] {
        println!(
            "  {name:>10}: {}/step, {} net/step, {}/step moved, cache hit {:.0}%",
            fmt_secs(r.mean_step_time()),
            fmt_secs(r.mean_net_time_per_step()),
            fmt_bytes(r.mean_bytes_per_step()),
            r.cache_hit_rate() * 100.0,
        );
    }
    println!(
        "  speedup: {:.2}x step, {:.2}x network, {:.2}x fewer remote rows",
        baseline.mean_step_time() / rapid.mean_step_time(),
        baseline.mean_net_time_per_step() / rapid.mean_net_time_per_step(),
        baseline.total_remote_rows() as f64 / rapid.total_remote_rows() as f64,
    );
    Ok(())
}
