//! Cache-size tuning: the interactive analogue of paper Fig. 5.
//!
//! Sweeps the hot-set size `n_hot` (and prefetch window Q) on products-sim
//! and prints remote fetches per epoch and hit rates — showing the
//! steep-then-flat long-tail payoff that makes cache sizing practical.
//!
//! ```bash
//! cargo run --release --example cache_tuning
//! ```

use rapidgnn::config::{DatasetConfig, DatasetPreset, Engine, RunConfig};
use rapidgnn::coordinator;
use rapidgnn::util::bench::{fmt_secs, Table};

fn main() -> rapidgnn::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.dataset = DatasetConfig::preset(DatasetPreset::ProductsSim, 0.2);
    cfg.engine = Engine::Rapid;
    cfg.num_workers = 2;
    cfg.batch_size = 512;
    cfg.epochs = 3;

    println!(
        "cache tuning on {} ({} nodes), batch {}, {} epochs",
        cfg.dataset.name, cfg.dataset.num_nodes, cfg.batch_size, cfg.epochs
    );

    let mut t = Table::new(
        "n_hot sweep (Q=4)",
        &["n_hot", "remote rows/epoch", "hit rate", "step time", "device MB"],
    );
    for n_hot in [0u32, 500, 1_000, 2_000, 4_000, 8_000, 16_000, 32_000] {
        let mut c = cfg.clone();
        c.n_hot = n_hot.max(1); // n_hot=0 → effectively uncached (1 entry)
        let r = coordinator::run(&c)?;
        let rows_per_epoch = r.total_remote_rows() as f64 / c.epochs as f64 / c.num_workers as f64;
        t.row(&[
            n_hot.to_string(),
            format!("{rows_per_epoch:.0}"),
            format!("{:.1}%", r.cache_hit_rate() * 100.0),
            fmt_secs(r.mean_step_time()),
            format!("{:.1}", r.peak_device_bytes() as f64 / 1e6),
        ]);
    }
    t.print();

    let mut t = Table::new(
        "prefetch window sweep (n_hot=2000)",
        &["Q", "step time", "trainer stall/step"],
    );
    for q in [1u32, 2, 4, 8, 16] {
        let mut c = cfg.clone();
        c.n_hot = 2_000;
        c.prefetch_q = q;
        let r = coordinator::run(&c)?;
        t.row(&[
            q.to_string(),
            fmt_secs(r.mean_step_time()),
            fmt_secs(r.mean_net_time_per_step()),
        ]);
    }
    t.print();
    println!("(diminishing returns past the knee — pick the smallest n_hot/Q at the flat)");
    Ok(())
}
